//! Sequence packing (paper §2.1, Figure 3): the alternative to padding.
//!
//! Packing concatenates sequences into chunks up to the replica's token
//! capacity and uses block-diagonal causal masks to avoid
//! cross-contamination. The paper assumes padding for its experiments
//! (following LongAlign's quality findings) but notes "the proposed
//! designs can also be applied when packing is employed" — this module
//! provides that substrate: first-fit-decreasing packing, its token
//! efficiency, and the chunk loads the cost model consumes.

use crate::costmodel::BucketLoad;

/// One packed chunk: indices into the original batch + total real tokens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedChunk {
    pub members: Vec<usize>,
    pub tokens: u64,
}

/// First-fit-decreasing packing of `lengths` into chunks of at most
/// `budget` tokens. Sequences longer than the budget get a chunk of their
/// own (the caller routes those to bigger replicas; this mirrors bucket
/// support in the padding mode).
pub fn pack_ffd(lengths: &[u32], budget: u64) -> Vec<PackedChunk> {
    let mut order: Vec<usize> = (0..lengths.len()).collect();
    order.sort_by_key(|&i| std::cmp::Reverse(lengths[i]));
    let mut chunks: Vec<PackedChunk> = Vec::new();
    for i in order {
        let len = lengths[i] as u64;
        match chunks
            .iter_mut()
            .find(|c| c.tokens + len <= budget)
        {
            Some(c) => {
                c.members.push(i);
                c.tokens += len;
            }
            None => chunks.push(PackedChunk { members: vec![i], tokens: len }),
        }
    }
    chunks
}

/// Token efficiency of a packing: real tokens / (chunks × budget).
/// 1.0 = perfectly full chunks; padding's analogue is
/// `1 − padding_ratio`.
pub fn packing_efficiency(chunks: &[PackedChunk], budget: u64) -> f64 {
    if chunks.is_empty() {
        return 1.0;
    }
    let real: u64 = chunks.iter().map(|c| c.tokens).sum();
    real as f64 / (chunks.len() as u64 * budget) as f64
}

/// Convert packed chunks into the cost model's bucket loads: each chunk is
/// one "sequence" of its summed length (memory is linear in the summed
/// chunk length — paper §2.2), so a replica processing `k` chunks of
/// budget `M` pays `k` microbatches of `M` tokens.
pub fn chunk_loads(chunks: &[PackedChunk]) -> Vec<BucketLoad> {
    chunks
        .iter()
        .map(|c| BucketLoad { count: 1, padded_len: c.tokens })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::bucketing::{bucketize, BucketingOptions};
    use crate::util::Rng;

    #[test]
    fn packs_within_budget_and_covers_all() {
        let lengths = vec![100, 900, 300, 700, 550, 450, 50];
        let chunks = pack_ffd(&lengths, 1000);
        let mut seen: Vec<usize> = chunks.iter().flat_map(|c| c.members.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..lengths.len()).collect::<Vec<_>>());
        for c in &chunks {
            let total: u64 = c.members.iter().map(|&i| lengths[i] as u64).sum();
            assert_eq!(total, c.tokens);
            assert!(c.tokens <= 1000 || c.members.len() == 1);
        }
        // FFD on these lengths: (900+100) (700+300) (550+450) (50) = 4 chunks
        assert_eq!(chunks.len(), 4);
    }

    #[test]
    fn oversized_sequence_gets_own_chunk() {
        let chunks = pack_ffd(&[5000, 100], 1000);
        assert_eq!(chunks.len(), 2);
        assert_eq!(chunks[0].tokens, 5000);
    }

    #[test]
    fn efficiency_bounds() {
        let full = pack_ffd(&[500, 500, 500, 500], 1000);
        assert!((packing_efficiency(&full, 1000) - 1.0).abs() < 1e-12);
        let half = pack_ffd(&[500], 1000);
        assert!((packing_efficiency(&half, 1000) - 0.5).abs() < 1e-12);
        assert_eq!(packing_efficiency(&[], 1000), 1.0);
    }

    #[test]
    fn packing_beats_padding_on_skewed_batches() {
        // the paper's theory: packing wastes fewer tokens than padding on
        // heavy-tailed length distributions (it trades quality instead).
        let mut rng = Rng::new(21);
        let lengths: Vec<u32> =
            (0..400).map(|_| (rng.lognormal(5.3, 1.0) as u32).clamp(16, 8192)).collect();
        let budget = 8192u64;
        let chunks = pack_ffd(&lengths, budget);
        let pack_eff = packing_efficiency(&chunks, budget);
        let b = bucketize(&lengths, &BucketingOptions::default());
        let real: u64 = lengths.iter().map(|&l| l as u64).sum();
        let pad_eff = real as f64 / (real + b.padding_tokens) as f64;
        assert!(
            pack_eff > pad_eff,
            "packing {pack_eff:.3} <= padding {pad_eff:.3}"
        );
    }

    #[test]
    fn chunk_loads_roundtrip() {
        let chunks = pack_ffd(&[300, 300, 500], 600);
        let loads = chunk_loads(&chunks);
        assert_eq!(loads.len(), chunks.len());
        let total: u64 = loads.iter().map(|l| l.padded_len * l.count).sum();
        assert_eq!(total, 1100);
    }
}
