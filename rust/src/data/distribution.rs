//! Sequence-length distributions: lognormal mixtures fitted to summary
//! moments (mean, skewness) with truncation to a practical length range.

use crate::util::Rng;


/// A truncated two-component lognormal mixture over sequence lengths.
///
/// Component 0 is the body; the optional component 1 is a heavy tail used
/// for datasets whose kurtosis far exceeds what a single lognormal with the
/// right skew can produce (e.g. XSum: skew 7.49, kurtosis 371.8).
#[derive(Debug, Clone)]
pub struct LengthDistribution {
    pub mu: f64,
    pub sigma: f64,
    /// Tail component weight in [0, 1).
    pub tail_weight: f64,
    pub tail_mu: f64,
    pub tail_sigma: f64,
    pub min_len: u32,
    pub max_len: u32,
}

impl LengthDistribution {
    /// Plain truncated lognormal.
    pub fn lognormal(mu: f64, sigma: f64, min_len: u32, max_len: u32) -> Self {
        Self {
            mu,
            sigma,
            tail_weight: 0.0,
            tail_mu: mu,
            tail_sigma: sigma,
            min_len,
            max_len,
        }
    }

    /// Fit a single lognormal to (mean, skewness) via the standard relations
    ///
    ///   skew = (e^{σ²} + 2) √(e^{σ²} − 1),   mean = e^{μ + σ²/2}
    ///
    /// solving the skew equation for σ by bisection.
    pub fn fit(mean: f64, skewness: f64, min_len: u32, max_len: u32) -> Self {
        let skew = skewness.max(0.05);
        // bisect sigma in (0.01, 3.5]
        let skew_of = |s: f64| {
            let w = (s * s).exp();
            (w + 2.0) * (w - 1.0).sqrt()
        };
        let (mut lo, mut hi) = (0.01_f64, 3.5_f64);
        for _ in 0..80 {
            let mid = 0.5 * (lo + hi);
            if skew_of(mid) < skew {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let sigma = 0.5 * (lo + hi);
        let mu = mean.ln() - sigma * sigma / 2.0;
        Self::lognormal(mu, sigma, min_len, max_len)
    }

    /// Fit with an explicit heavy tail: `tail_weight` of the mass comes from
    /// a second lognormal centered `tail_ratio`× above the body mean.
    pub fn fit_heavy_tail(
        mean: f64,
        skewness: f64,
        tail_weight: f64,
        tail_ratio: f64,
        min_len: u32,
        max_len: u32,
    ) -> Self {
        assert!((0.0..1.0).contains(&tail_weight));
        // Body mean adjusted so the mixture hits the target mean.
        let tail_mean = mean * tail_ratio;
        let body_mean =
            (mean - tail_weight * tail_mean) / (1.0 - tail_weight);
        let body = Self::fit(body_mean.max(8.0), skewness, min_len, max_len);
        let tail_sigma = 0.6;
        let tail_mu = tail_mean.ln() - tail_sigma * tail_sigma / 2.0;
        Self {
            mu: body.mu,
            sigma: body.sigma,
            tail_weight,
            tail_mu,
            tail_sigma,
            min_len,
            max_len,
        }
    }

    /// Draw one sequence length.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let x = if self.tail_weight > 0.0 && rng.f64() < self.tail_weight {
            rng.lognormal(self.tail_mu, self.tail_sigma)
        } else {
            rng.lognormal(self.mu, self.sigma)
        };
        (x.round() as u32).clamp(self.min_len, self.max_len)
    }

    /// Draw `n` lengths.
    pub fn sample_n(&self, rng: &mut Rng, n: usize) -> Vec<u32> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    /// Analytic (untruncated) mean of the mixture — used for sanity tests.
    pub fn analytic_mean(&self) -> f64 {
        let body = (self.mu + self.sigma * self.sigma / 2.0).exp();
        let tail = (self.tail_mu + self.tail_sigma * self.tail_sigma / 2.0).exp();
        (1.0 - self.tail_weight) * body + self.tail_weight * tail
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::moments;

    fn sample_f64(d: &LengthDistribution, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        d.sample_n(&mut rng, n).into_iter().map(|x| x as f64).collect()
    }

    #[test]
    fn fit_recovers_mean() {
        for (mean, skew) in [(207.0, 7.11), (663.0, 0.79), (3903.0, 0.85)] {
            let d = LengthDistribution::fit(mean, skew, 16, 32768);
            let xs = sample_f64(&d, 100_000, 1);
            let m = moments(&xs);
            // truncation + heavy tails: allow 15%
            assert!(
                (m.mean - mean).abs() / mean < 0.15,
                "mean {} target {mean}",
                m.mean
            );
        }
    }

    #[test]
    fn fit_recovers_skew_direction() {
        let high = LengthDistribution::fit(500.0, 7.0, 16, 32768);
        let low = LengthDistribution::fit(500.0, 0.8, 16, 32768);
        let mh = moments(&sample_f64(&high, 200_000, 2));
        let ml = moments(&sample_f64(&low, 200_000, 3));
        assert!(mh.skewness > ml.skewness + 1.0, "{} vs {}", mh.skewness, ml.skewness);
        assert!(ml.skewness > 0.2 && ml.skewness < 2.5, "{}", ml.skewness);
    }

    #[test]
    fn bounds_respected() {
        let d = LengthDistribution::fit(100.0, 5.0, 32, 1024);
        let mut rng = Rng::new(4);
        for _ in 0..10_000 {
            let x = d.sample(&mut rng);
            assert!((32..=1024).contains(&x));
        }
    }

    #[test]
    fn heavy_tail_raises_kurtosis() {
        let plain = LengthDistribution::fit(526.0, 2.0, 16, 32768);
        let heavy =
            LengthDistribution::fit_heavy_tail(526.0, 2.0, 0.02, 8.0, 16, 32768);
        let kp = moments(&sample_f64(&plain, 200_000, 5)).kurtosis;
        let kh = moments(&sample_f64(&heavy, 200_000, 6)).kurtosis;
        assert!(kh > kp, "heavy {kh} <= plain {kp}");
    }

    #[test]
    fn most_sequences_short_skewness_property() {
        // Paper §3: "most sequences are relatively short" — median < mean.
        let d = LengthDistribution::fit(947.0, 0.89, 16, 32768);
        let xs = sample_f64(&d, 50_000, 7);
        let m = moments(&xs);
        let med = crate::util::stats::quantile(&xs, 0.5);
        assert!(med < m.mean, "median {med} mean {}", m.mean);
    }
}
