//! Backend-agnostic per-step execution of dispatched replica workloads.
//!
//! LobRA's headline claim is that per-step MINMAX dispatching over
//! heterogeneous FT replicas balances sequence-length skew. That claim is
//! only as good as the execution layer that realizes it: before this module
//! existed, the simulated step loop ([`crate::coordinator::scheduler`])
//! inlined its own cost-model arithmetic while the real PJRT training loop
//! ([`crate::train`]) round-robined microbatch shapes over replicas — two
//! different executions of two different workload assignments, neither
//! shared with the other. This module is the single execution layer both
//! now route through:
//!
//! ```text
//!   MultiTaskSampler ──► bucketize ──► Dispatcher::dispatch (MINMAX solve)
//!                                              │
//!                                     ExecutionPlan::build
//!                              (per-replica BucketLoads + concrete
//!                               sequence assignment, group-major order)
//!                                              │
//!                      ┌───────────────────────┴───────────────────────┐
//!                      ▼            ReplicaExecutor                    ▼
//!              ┌──────────────┐                              ┌──────────────────┐
//!              │ SimExecutor  │  advances the cost-model     │  PjrtExecutor    │
//!              │ (cost clock) │  clock per replica; bit-     │ (runtime::Engine)│
//!              │              │  identical to the dispatch   │  maps BucketLoads│
//!              │              │  solve's predicted times     │  to compiled     │
//!              └──────┬───────┘                              │  (batch, seq)    │
//!                     │                                      │  artifacts, runs │
//!                     │                                      │  replicas via    │
//!                     │                                      │  util::par       │
//!                     │                                      └────────┬─────────┘
//!                     ▼                                               ▼
//!        StepExecution { replica_seconds, step_time, observations, [TrainOutput] }
//! ```
//!
//! Both backends account the *virtual-cluster clock* identically — per
//! replica, the cost model's `replica_time` over its dispatched loads; per
//! step, the max over replicas plus the synchronous LoRA sync — so the
//! GPU-seconds reported by simulated benches and by real `lobra train` runs
//! come from the same dispatch code path. For serving workloads whose
//! deployment is *replaced* mid-run, [`SimTrainLoop`] wraps the same
//! pipeline behind an owned, step-boundary-swappable plan (see
//! [`crate::coordinator::runtime`]). The real backend additionally
//! executes the assignment on the PJRT engine (replicas run concurrently
//! via [`crate::util::par`]) and reduces gradients deterministically:
//! per-replica partials are combined in fixed replica order with a
//! token-weighted binary-tree reduction ([`tree_reduce`]), so results are
//! seed-reproducible regardless of `LOBRA_NUM_THREADS`.

mod pjrt;
mod sim;
mod steploop;

pub use pjrt::{materialize_assignment, Microbatch, PjrtExecutor};
pub use sim::SimExecutor;
pub use steploop::{SimStep, SimTrainLoop};

use std::collections::VecDeque;
use std::sync::Arc;

use crate::config::{ParallelConfig, TaskSet};
use crate::coordinator::bucketing::{bucketize, BucketingOptions, Buckets};
use crate::coordinator::dispatcher::{DispatchPlan, DispatchPolicy, Dispatcher};
use crate::coordinator::planner::DeploymentPlan;
use crate::costmodel::{BucketLoad, CalibrationStore, CostModel, CostTable, Observation};
use crate::data::{FusedBatch, MultiTaskSampler, Sequence};
use crate::util::clock::Stopwatch;
use anyhow::Result;

/// One replica's workload for one step: its dispatched bucket loads plus
/// the concrete sequences backing them (grouped per load, same order).
#[derive(Debug, Clone)]
pub struct ReplicaAssignment {
    /// Global replica index (group-major, fixed across the run).
    pub replica: usize,
    /// Index of the owning group in the deployment plan.
    pub group: usize,
    pub config: ParallelConfig,
    /// Dispatched loads, exactly as timed by `Dispatcher::evaluate`.
    pub loads: Vec<BucketLoad>,
    /// Concrete sequences per load (parallel to `loads`; each inner vec has
    /// `loads[k].count` entries).
    pub sequences: Vec<Vec<Sequence>>,
}

impl ReplicaAssignment {
    /// Total sequences assigned to this replica.
    pub fn n_sequences(&self) -> u64 {
        self.loads.iter().map(|l| l.count).sum()
    }
}

/// A fully-resolved step workload: the fused batch, its buckets, the MINMAX
/// dispatch solve, and the per-replica assignment of concrete sequences —
/// everything an executor backend needs, and nothing it must re-derive.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    pub batch: FusedBatch,
    pub buckets: Buckets,
    pub dispatch: DispatchPlan,
    /// Per-replica workloads, group-major (fixed replica order).
    pub assignments: Vec<ReplicaAssignment>,
    /// Deployment-wide constants for the sync-time term.
    pub n_replicas: u32,
    pub n_tasks: u32,
    /// Wall-clock of the dispatch solve alone (the overlappable per-step
    /// planning cost — excludes the concrete-sequence deal-out below).
    pub solve_seconds: f64,
    /// Cost table the dispatch was solved with (if any); executors read
    /// replica times through it so execution is bit-identical to the solve.
    pub table: Option<Arc<CostTable>>,
}

impl ExecutionPlan {
    /// Run the coordinator pipeline tail for one step: solve the dispatch
    /// over `buckets` and deal the batch's concrete sequences onto replicas
    /// in deterministic (bucket-queue, group-major) order.
    ///
    /// Returns `None` when the deployment cannot serve the batch (some
    /// bucket is infeasible on every group).
    pub fn build(
        cost: &CostModel,
        deployment: &DeploymentPlan,
        table: Option<Arc<CostTable>>,
        batch: FusedBatch,
        buckets: Buckets,
        policy: DispatchPolicy,
    ) -> Option<ExecutionPlan> {
        let t0 = Stopwatch::start();
        let dispatch = match &table {
            Some(t) => {
                Dispatcher::with_table(cost, deployment, t).dispatch(&buckets, policy)?
            }
            None => Dispatcher::new(cost, deployment).dispatch(&buckets, policy)?,
        };
        let solve_seconds = t0.elapsed_secs();

        // Deal concrete sequences: per bucket, a FIFO queue in batch order;
        // replicas draw from it in fixed group-major order. Deterministic
        // given (batch, dispatch), independent of any thread timing.
        let nb = buckets.boundaries.len();
        let mut queues: Vec<VecDeque<Sequence>> = vec![VecDeque::new(); nb];
        for s in &batch.sequences {
            queues[buckets.bucket_of(s.len)].push_back(*s);
        }

        let mut assignments = Vec::with_capacity(dispatch.replica_assignments.len());
        let mut replica = 0usize;
        let mut group = 0usize;
        let mut left_in_group = dispatch.groups.first().map_or(0, |&(_, p)| p.max(1));
        for loads in &dispatch.replica_assignments {
            while left_in_group == 0 {
                group += 1;
                left_in_group = dispatch.groups[group].1.max(1);
            }
            let config = dispatch.groups[group].0;
            let mut sequences = Vec::with_capacity(loads.len());
            for load in loads {
                // padded_len is always one of the solve's boundary values
                let j = buckets.bucket_of(load.padded_len as u32);
                debug_assert_eq!(buckets.boundaries[j] as u64, load.padded_len);
                let mut seqs = Vec::with_capacity(load.count as usize);
                for _ in 0..load.count {
                    seqs.push(queues[j].pop_front()?);
                }
                sequences.push(seqs);
            }
            assignments.push(ReplicaAssignment {
                replica,
                group,
                config,
                loads: loads.clone(),
                sequences,
            });
            replica += 1;
            left_in_group -= 1;
        }

        Some(ExecutionPlan {
            batch,
            buckets,
            dispatch,
            assignments,
            n_replicas: deployment.n_replicas(),
            n_tasks: deployment.n_tasks,
            solve_seconds,
            table,
        })
    }

    /// Total sequences across all replica assignments.
    pub fn total_assigned(&self) -> u64 {
        self.assignments.iter().map(|a| a.n_sequences()).sum()
    }
}

/// What a backend reports for one executed step.
#[derive(Debug, Clone)]
pub struct StepExecution {
    /// Per-replica virtual busy seconds, group-major (feeds `GpuLedger`).
    pub replica_seconds: Vec<(ParallelConfig, f64)>,
    /// Virtual-cluster step wall-clock: max replica time + LoRA sync.
    pub step_time: f64,
    /// Real host wall-clock spent executing (0 for the simulated backend).
    pub wall_seconds: f64,
    /// One `(b, s, seconds)` [`Observation`] per executed microbatch,
    /// tagged with the replica configuration it ran under — the raw feed
    /// of [`CalibrationStore`]. PJRT reports measured wall-clocks for
    /// single-GPU configs (the local engine realizes no tp/pp stages, so
    /// multi-GPU replicas keep analytic constants); the sim backend, in
    /// profiling mode ([`SimExecutor::profiling`]), reports exact
    /// cost-model chunk times (the deterministic test double). Empty on
    /// the plain scheduler path.
    pub observations: Vec<(ParallelConfig, Observation)>,
    /// Real-backend training outputs (gradients, losses); `None` for sim.
    pub train: Option<TrainOutput>,
}

/// Aggregated training outputs of one real (engine-executed) step.
#[derive(Debug, Clone)]
pub struct TrainOutput {
    /// Token-weighted gradient *sum* over all microbatches (divide by
    /// `tokens` for the mean the optimizer consumes).
    pub grad: Vec<f32>,
    /// Token-weighted loss sum.
    pub loss_sum: f64,
    /// Total target tokens.
    pub tokens: f64,
    /// Per-task loss sums / token counts.
    pub task_loss: Vec<f64>,
    pub task_tokens: Vec<f64>,
    /// Microbatches executed across all replicas.
    pub microbatches: usize,
}

/// A per-step replica executor backend.
///
/// Contract: `execute_step` runs every [`ReplicaAssignment`] in
/// `plan.assignments` and reports per-replica virtual busy seconds in the
/// same (group-major) order, with `step_time = max(replica) + sync` — the
/// exact accounting of the dispatch solve, so a backend swap never changes
/// the reported GPU-seconds model.
pub trait ReplicaExecutor {
    /// Stable backend name for logs and reports.
    fn backend(&self) -> &'static str;

    /// Execute one step's assignments.
    fn execute_step(&mut self, plan: &ExecutionPlan) -> Result<StepExecution>;
}

/// Virtual-cluster accounting shared by both backends: per-replica busy
/// time via the cost table (bit-identical to the dispatch solve when the
/// plan carries the table it was solved with), max-folded in fixed replica
/// order, plus the synchronous LoRA sync.
pub(crate) fn virtual_clock(
    cost: &CostModel,
    plan: &ExecutionPlan,
) -> (Vec<(ParallelConfig, f64)>, f64) {
    let mut replica_seconds = Vec::with_capacity(plan.assignments.len());
    let mut busiest: f64 = 0.0;
    for a in &plan.assignments {
        let t = match &plan.table {
            Some(table) => table.replica_time(a.config, &a.loads),
            None => cost.replica_time(a.config, &a.loads),
        };
        busiest = busiest.max(t);
        replica_seconds.push((a.config, t));
    }
    let sync = cost.sync_time(plan.n_replicas, plan.n_tasks.max(1));
    (replica_seconds, busiest + sync)
}

/// Run `steps` simulated profiling steps of `plan` over `tasks` and feed
/// every emitted microbatch observation into `store` — the sim-backed
/// calibration loop behind `lobra calibrate`, `benches/calibration.rs` and
/// the calibration tests. Each step samples a fused batch, bucketizes it,
/// solves the MINMAX dispatch and "executes" it on the [`SimExecutor`]
/// clock; steps whose batch the deployment cannot serve are skipped.
/// Returns the number of observations recorded.
pub fn profile_sim_steps(
    cost: &CostModel,
    plan: &DeploymentPlan,
    tasks: &TaskSet,
    steps: usize,
    seed: u64,
    store: &mut CalibrationStore,
) -> usize {
    let mut sampler = MultiTaskSampler::new(tasks, seed);
    let mut exec = SimExecutor::profiling(cost);
    let mut recorded = 0usize;
    for _ in 0..steps {
        let batch = sampler.next_batch();
        let buckets = bucketize(&batch.lengths(), &BucketingOptions::default());
        let Some(eplan) = ExecutionPlan::build(
            cost,
            plan,
            None,
            batch,
            buckets,
            DispatchPolicy::Balanced,
        ) else {
            continue;
        };
        let Ok(out) = exec.execute_step(&eplan) else {
            continue;
        };
        recorded += out.observations.len();
        store.record_all(&out.observations);
    }
    recorded
}

// `tree_reduce` moved to `util::par` so the staged runtime's tensor-
// parallel all-reduce can share the exact combine ordering the gradient
// reduction here uses; re-exported to keep the established path working.
pub use crate::util::par::tree_reduce;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::{ModelDesc, TaskSet};
    use crate::coordinator::bucketing::{bucketize, BucketingOptions};
    use crate::coordinator::planner::{Planner, PlannerOptions};
    use crate::data::MultiTaskSampler;

    fn world() -> (CostModel, DeploymentPlan, TaskSet) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let planner = Planner::new(&cost, &cluster);
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        (cost, plan, tasks)
    }

    #[test]
    fn plan_partitions_batch_exactly() {
        let (cost, plan, tasks) = world();
        let mut sampler = MultiTaskSampler::new(&tasks, 3);
        for _ in 0..5 {
            let batch = sampler.next_batch();
            let n = batch.len() as u64;
            let buckets = bucketize(&batch.lengths(), &BucketingOptions::default());
            let ep = ExecutionPlan::build(
                &cost,
                &plan,
                None,
                batch,
                buckets,
                DispatchPolicy::Balanced,
            )
            .unwrap();
            assert_eq!(ep.total_assigned(), n);
            assert_eq!(ep.dispatch.total_sequences(), n);
            // every load's concrete sequences fit its bucket's pad length
            for a in &ep.assignments {
                assert_eq!(a.loads.len(), a.sequences.len());
                for (load, seqs) in a.loads.iter().zip(&a.sequences) {
                    assert_eq!(load.count as usize, seqs.len());
                    for s in seqs {
                        assert!(
                            (s.len as u64) <= load.padded_len,
                            "len {} over pad {}",
                            s.len,
                            load.padded_len
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn assignment_order_is_group_major() {
        let (cost, plan, tasks) = world();
        let mut sampler = MultiTaskSampler::new(&tasks, 5);
        let batch = sampler.next_batch();
        let buckets = bucketize(&batch.lengths(), &BucketingOptions::default());
        let ep = ExecutionPlan::build(
            &cost,
            &plan,
            None,
            batch,
            buckets,
            DispatchPolicy::Balanced,
        )
        .unwrap();
        assert_eq!(ep.assignments.len(), ep.dispatch.replica_times.len());
        let mut expect = Vec::new();
        for (gi, &(cfg, p)) in plan.groups.iter().enumerate() {
            for _ in 0..p.max(1) {
                expect.push((gi, cfg));
            }
        }
        for (a, (gi, cfg)) in ep.assignments.iter().zip(expect) {
            assert_eq!(a.group, gi);
            assert_eq!(a.config, cfg);
        }
        for (i, a) in ep.assignments.iter().enumerate() {
            assert_eq!(a.replica, i);
        }
    }

    #[test]
    fn tree_reduce_matches_linear_for_ints() {
        for n in [0usize, 1, 2, 3, 7, 8, 33] {
            let xs: Vec<u64> = (0..n as u64).collect();
            let tree = tree_reduce(xs.clone(), |a, b| a + b);
            assert_eq!(tree, xs.iter().copied().reduce(|a, b| a + b));
        }
    }

    #[test]
    fn tree_reduce_shape_is_input_order() {
        // order-sensitive combine certifies the pairing is positional
        let xs: Vec<String> = (0..5).map(|i| i.to_string()).collect();
        let r = tree_reduce(xs, |a, b| format!("({a}{b})")).unwrap();
        assert_eq!(r, "(((01)(23))4)");
    }
}
