//! Simulated backend: executing a replica = advancing the cost-model clock.

use super::{virtual_clock, ExecutionPlan, ReplicaExecutor, StepExecution};
use crate::costmodel::CostModel;
use anyhow::Result;

/// Cost-model-clock executor — the engine behind every simulated bench.
///
/// "Executing" a [`super::ReplicaAssignment`] evaluates the cost model's
/// `replica_time` over the replica's dispatched loads, through the same
/// [`crate::costmodel::CostTable`] the dispatch was solved with, so the
/// resulting step time is bit-identical to the solve's
/// `predicted_step_time`. This replaces the arithmetic that used to live
/// inline in `Scheduler::step`; the scheduler is now a thin loop over this
/// executor, and real runs ([`super::PjrtExecutor`]) account their virtual
/// clock with the identical code path.
pub struct SimExecutor<'a> {
    cost: &'a CostModel,
}

impl<'a> SimExecutor<'a> {
    pub fn new(cost: &'a CostModel) -> Self {
        Self { cost }
    }
}

impl ReplicaExecutor for SimExecutor<'_> {
    fn backend(&self) -> &'static str {
        "sim"
    }

    fn execute_step(&mut self, plan: &ExecutionPlan) -> Result<StepExecution> {
        let (replica_seconds, step_time) = virtual_clock(self.cost, plan);
        Ok(StepExecution { replica_seconds, step_time, wall_seconds: 0.0, train: None })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::{ModelDesc, TaskSet};
    use crate::coordinator::bucketing::{bucketize, BucketingOptions};
    use crate::coordinator::dispatcher::DispatchPolicy;
    use crate::coordinator::planner::{Planner, PlannerOptions};
    use crate::data::MultiTaskSampler;

    #[test]
    fn sim_step_time_matches_dispatch_prediction_bitwise() {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let plan = Planner::new(&cost, &cluster)
            .plan(&tasks, PlannerOptions::default())
            .unwrap();
        let mut sampler = MultiTaskSampler::new(&tasks, 11);
        let mut exec = SimExecutor::new(&cost);
        for policy in [DispatchPolicy::Balanced, DispatchPolicy::LengthBased] {
            for _ in 0..6 {
                let batch = sampler.next_batch();
                let buckets = bucketize(&batch.lengths(), &BucketingOptions::default());
                let ep =
                    ExecutionPlan::build(&cost, &plan, None, batch, buckets, policy)
                        .unwrap();
                let out = exec.execute_step(&ep).unwrap();
                assert_eq!(
                    out.step_time.to_bits(),
                    ep.dispatch.predicted_step_time.to_bits(),
                    "executor re-derived a different clock than the solve"
                );
                assert_eq!(out.replica_seconds.len(), ep.dispatch.replica_times.len());
                for (a, b) in out.replica_seconds.iter().zip(&ep.dispatch.replica_times)
                {
                    assert_eq!(a.0, b.0);
                    assert_eq!(a.1.to_bits(), b.1.to_bits());
                }
                assert!(out.train.is_none());
            }
        }
    }
}
