//! Simulated backend: executing a replica = advancing the cost-model clock.

use super::{virtual_clock, ExecutionPlan, ReplicaExecutor, StepExecution};
use crate::costmodel::{CostModel, Observation};
use anyhow::Result;

/// Cost-model-clock executor — the engine behind every simulated bench.
///
/// "Executing" a [`super::ReplicaAssignment`] evaluates the cost model's
/// `replica_time` over the replica's dispatched loads, through the same
/// [`crate::costmodel::CostTable`] the dispatch was solved with, so the
/// resulting step time is bit-identical to the solve's
/// `predicted_step_time`. This replaces the arithmetic that used to live
/// inline in `Scheduler::step`; the scheduler is now a thin loop over this
/// executor, and real runs ([`super::PjrtExecutor`]) account their virtual
/// clock with the identical code path.
pub struct SimExecutor<'a> {
    cost: &'a CostModel,
    /// Emit per-chunk [`Observation`]s in [`super::StepExecution`]. Off by
    /// default: the scheduler's step loop (and every sim bench timed
    /// through it) drops them, so the O(chunks) emission would be pure
    /// overhead on the path whose wall-clock the benches record.
    record_observations: bool,
}

impl<'a> SimExecutor<'a> {
    pub fn new(cost: &'a CostModel) -> Self {
        Self { cost, record_observations: false }
    }

    /// A profiling-mode executor: every executed chunk is reported as an
    /// exact analytic [`Observation`] (the calibration test double). Used
    /// by [`super::profile_sim_steps`].
    pub fn profiling(cost: &'a CostModel) -> Self {
        Self { cost, record_observations: true }
    }
}

impl ReplicaExecutor for SimExecutor<'_> {
    fn backend(&self) -> &'static str {
        "sim"
    }

    fn execute_step(&mut self, plan: &ExecutionPlan) -> Result<StepExecution> {
        let (replica_seconds, step_time) = virtual_clock(self.cost, plan);
        // In profiling mode: one observation per "executed" microbatch,
        // mirroring what the real backend reports — except every field is
        // exact analytic arithmetic, which makes this the deterministic
        // test double for the calibration loop. Multi-GPU configurations
        // attribute their analytic TP/PP comm and an even per-chunk share
        // of the pipeline bubble ((pp−1)·max chunk time, exactly as
        // `replica_time` charges it), so a fit over these observations
        // regresses the analytic compute family and reproduces the cost
        // model it was sampled from.
        let mut observations = Vec::new();
        if self.record_observations {
            for a in &plan.assignments {
                // Pre-pass: this assignment's bubble, spread over its chunks.
                let mut max_chunk_t: f64 = 0.0;
                let mut n_chunks: u64 = 0;
                for load in &a.loads {
                    if load.count == 0 {
                        continue;
                    }
                    let cp = self.cost.chunks_for(a.config, load.count, load.padded_len);
                    if cp.full_chunks > 0 {
                        let t =
                            self.cost.t_microbatch(a.config, cp.per_chunk, load.padded_len);
                        max_chunk_t = max_chunk_t.max(t);
                    }
                    if cp.remainder > 0 {
                        let t =
                            self.cost.t_microbatch(a.config, cp.remainder, load.padded_len);
                        max_chunk_t = max_chunk_t.max(t);
                    }
                    n_chunks += cp.n_chunks();
                }
                if n_chunks == 0 {
                    continue;
                }
                let bubble_share =
                    (a.config.pp as f64 - 1.0) * max_chunk_t / n_chunks as f64;
                for load in &a.loads {
                    if load.count == 0 {
                        continue;
                    }
                    let cp = self.cost.chunks_for(a.config, load.count, load.padded_len);
                    let mut emit = |b: u64| {
                        let t = self.cost.t_microbatch(a.config, b, load.padded_len);
                        let m = self.cost.microbatch_breakdown(a.config, b, load.padded_len);
                        observations.push((
                            a.config,
                            Observation::with_overheads(
                                b,
                                load.padded_len,
                                t + bubble_share,
                                m.tp_comm + m.pp_comm,
                                bubble_share,
                            ),
                        ));
                    };
                    for _ in 0..cp.full_chunks {
                        emit(cp.per_chunk);
                    }
                    if cp.remainder > 0 {
                        emit(cp.remainder);
                    }
                }
            }
        }
        Ok(StepExecution {
            replica_seconds,
            step_time,
            wall_seconds: 0.0,
            observations,
            train: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::{ModelDesc, TaskSet};
    use crate::coordinator::bucketing::{bucketize, BucketingOptions};
    use crate::coordinator::dispatcher::DispatchPolicy;
    use crate::coordinator::planner::{Planner, PlannerOptions};
    use crate::data::MultiTaskSampler;

    #[test]
    fn sim_step_time_matches_dispatch_prediction_bitwise() {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let plan = Planner::new(&cost, &cluster)
            .plan(&tasks, PlannerOptions::default())
            .unwrap();
        let mut sampler = MultiTaskSampler::new(&tasks, 11);
        let mut exec = SimExecutor::new(&cost);
        for policy in [DispatchPolicy::Balanced, DispatchPolicy::LengthBased] {
            for _ in 0..6 {
                let batch = sampler.next_batch();
                let buckets = bucketize(&batch.lengths(), &BucketingOptions::default());
                let ep =
                    ExecutionPlan::build(&cost, &plan, None, batch, buckets, policy)
                        .unwrap();
                let out = exec.execute_step(&ep).unwrap();
                assert_eq!(
                    out.step_time.to_bits(),
                    ep.dispatch.predicted_step_time.to_bits(),
                    "executor re-derived a different clock than the solve"
                );
                assert_eq!(out.replica_seconds.len(), ep.dispatch.replica_times.len());
                for (a, b) in out.replica_seconds.iter().zip(&ep.dispatch.replica_times)
                {
                    assert_eq!(a.0, b.0);
                    assert_eq!(a.1.to_bits(), b.1.to_bits());
                }
                assert!(out.train.is_none());
            }
        }
    }

    #[test]
    fn sim_observations_are_exact_chunk_times() {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        let tasks = TaskSet::paper_7b_subset();
        let plan = Planner::new(&cost, &cluster)
            .plan(&tasks, PlannerOptions::default())
            .unwrap();
        let mut sampler = MultiTaskSampler::new(&tasks, 3);
        let batch = sampler.next_batch();
        let buckets = bucketize(&batch.lengths(), &BucketingOptions::default());
        let ep = ExecutionPlan::build(
            &cost,
            &plan,
            None,
            batch,
            buckets,
            DispatchPolicy::Balanced,
        )
        .unwrap();
        // the default (scheduler-path) executor emits nothing ...
        let silent = SimExecutor::new(&cost).execute_step(&ep).unwrap();
        assert!(silent.observations.is_empty());
        // ... the profiling executor emits one observation per chunk of
        // every dispatched load ...
        let out = SimExecutor::profiling(&cost).execute_step(&ep).unwrap();
        let expected: u64 = ep
            .assignments
            .iter()
            .map(|a| {
                a.loads
                    .iter()
                    .filter(|l| l.count > 0)
                    .map(|l| cost.chunks_for(a.config, l.count, l.padded_len).n_chunks())
                    .sum::<u64>()
            })
            .sum();
        assert!(expected > 0);
        assert_eq!(out.observations.len() as u64, expected);
        // ... bit-identical to the analytic chunk time plus the chunk's
        // bubble share (zero for pp=1), with comm attributed exactly ...
        for (cfg, o) in &out.observations {
            assert_eq!(
                (cost.t_microbatch(*cfg, o.b, o.s) + o.bubble).to_bits(),
                o.seconds.to_bits(),
                "{cfg} b={} s={}",
                o.b,
                o.s
            );
            let m = cost.microbatch_breakdown(*cfg, o.b, o.s);
            assert_eq!(o.comm.to_bits(), (m.tp_comm + m.pp_comm).to_bits());
            if cfg.pp == 1 {
                assert_eq!(o.bubble.to_bits(), 0.0f64.to_bits());
            }
        }
        // ... and accounting every dispatched sequence exactly once
        let obs_seqs: u64 = out.observations.iter().map(|(_, o)| o.b).sum();
        assert_eq!(obs_seqs, ep.total_assigned());
    }
}
