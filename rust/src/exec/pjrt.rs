//! Real backend: dispatched loads executed on the PJRT engine.

use super::{
    tree_reduce, virtual_clock, ExecutionPlan, ReplicaAssignment, ReplicaExecutor,
    StepExecution, TrainOutput,
};
use crate::config::ParallelConfig;
use crate::costmodel::{CostModel, Observation};
use crate::data::SyntheticCorpus;
use crate::runtime::{
    Engine, NativeModel, ParamVector, StageMb, StagedEngine, StepOutput,
};
use crate::util::clock::Stopwatch;
use crate::util::par::par_map;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// One engine-executable microbatch materialized from a dispatched load.
#[derive(Debug, Clone)]
pub struct Microbatch {
    /// Compiled artifact shape `(batch, seq)` this microbatch targets.
    pub shape: (u64, u64),
    /// Row-major `[b, s]` tokens, PAD = 0.
    pub tokens: Vec<i32>,
    /// Sorted per-row task ids (the L1 kernel contract).
    pub seg_ids: Vec<i32>,
    /// Rows backed by real sequences; rows `real_rows..b` are PAD rows
    /// (all-zero tokens) that contribute no targets to loss or gradient.
    pub real_rows: usize,
}

/// Materialize one replica's dispatched loads into engine microbatches.
///
/// Each [`crate::costmodel::BucketLoad`] maps to the compiled artifact
/// whose `seq` matches the bucket's pad length (smallest covering shape,
/// falling back to the largest, which truncates over-long sequences); its
/// sequences are chunked into groups of the artifact's batch size, sorted
/// by task id within each chunk. A final partial chunk is completed with
/// true PAD rows — all-zero token rows with zero targets — never by
/// repeating a real sequence, which would double-count its gradient.
pub fn materialize_assignment(
    corpus: &mut SyntheticCorpus,
    shapes: &[(u64, u64)],
    assignment: &ReplicaAssignment,
) -> Vec<Microbatch> {
    let mut out = Vec::new();
    for (load, seqs) in assignment.loads.iter().zip(&assignment.sequences) {
        if load.count == 0 {
            continue;
        }
        let si = shapes
            .iter()
            .position(|&(_, s)| s >= load.padded_len)
            .unwrap_or(shapes.len() - 1);
        let (b, s) = shapes[si];
        for chunk in seqs.chunks(b as usize) {
            let mut rows: Vec<_> = chunk.to_vec();
            rows.sort_unstable_by_key(|r| r.task);
            let mut tokens = Vec::with_capacity((b * s) as usize);
            let mut seg_ids = Vec::with_capacity(b as usize);
            for r in &rows {
                tokens.extend(corpus.sequence_exact(
                    r.task as usize,
                    r.len as usize,
                    s as usize,
                ));
                seg_ids.push(r.task as i32);
            }
            // PAD rows: zero tokens (no targets), seg id repeats the last
            // real row's task to keep the sorted-seg-ids kernel contract.
            let pad_seg = seg_ids.last().copied().unwrap_or(0);
            for _ in rows.len()..b as usize {
                tokens.resize(tokens.len() + s as usize, 0);
                seg_ids.push(pad_seg);
            }
            out.push(Microbatch {
                shape: (b, s),
                tokens,
                seg_ids,
                real_rows: rows.len(),
            });
        }
    }
    out
}

/// Per-replica training partial, combined by a deterministic tree
/// reduction in fixed replica order.
struct ReplicaPartial {
    grad: Vec<f32>,
    loss_sum: f64,
    tokens: f64,
    task_loss: Vec<f64>,
    task_tokens: Vec<f64>,
    microbatches: usize,
    /// Per-microbatch wall-clock observations, tagged with the replica's
    /// parallel configuration (the in-situ calibration feed).
    observations: Vec<(ParallelConfig, Observation)>,
}

impl ReplicaPartial {
    fn empty(n_params: usize, n_tasks: usize) -> Self {
        Self {
            grad: vec![0.0; n_params],
            loss_sum: 0.0,
            tokens: 0.0,
            task_loss: vec![0.0; n_tasks],
            task_tokens: vec![0.0; n_tasks],
            microbatches: 0,
            observations: Vec::new(),
        }
    }

    fn merge(mut self, other: Self) -> Self {
        for (g, o) in self.grad.iter_mut().zip(&other.grad) {
            *g += o;
        }
        self.loss_sum += other.loss_sum;
        self.tokens += other.tokens;
        for (a, b) in self.task_loss.iter_mut().zip(&other.task_loss) {
            *a += b;
        }
        for (a, b) in self.task_tokens.iter_mut().zip(&other.task_tokens) {
            *a += b;
        }
        self.microbatches += other.microbatches;
        self.observations.extend(other.observations);
        self
    }
}

/// The model runtime a [`PjrtExecutor`] executes microbatches on.
///
/// `Pjrt` wraps compiled HLO artifacts on the PJRT CPU client; it realizes
/// no tensor or pipeline parallelism, so only single-GPU replicas produce
/// meaningful per-stage timings there. `Native` wraps the pure-Rust
/// [`NativeModel`]: single-GPU replicas run its fused `train_step`, while
/// multi-GPU replicas are realized by [`StagedEngine`] — pp pipeline
/// stages over a 1F1B schedule with tp-sharded matmuls inside each stage —
/// which is what lets real (measured, not analytic) `(b, s, seconds)`
/// observations exist for `tp > 1` and `pp > 1` configurations.
enum EngineBackend {
    Pjrt(Engine),
    Native {
        model: Arc<NativeModel>,
        base: Arc<ParamVector>,
    },
}

/// Engine-backed executor: executes each replica's dispatched loads as
/// `(batch, seq)` microbatches on an [`EngineBackend`].
///
/// Replicas run concurrently via [`crate::util::par::par_map`] (the
/// vendored PJRT stub, the CPU client and the native model are shareable
/// across threads); microbatch materialization happens up front on one
/// thread so the corpus RNG stream — and therefore the training data — is
/// identical for every `LOBRA_NUM_THREADS` setting. Gradients are reduced
/// token-weighted in fixed replica order with [`tree_reduce`], so the
/// optimizer sees a bit-reproducible update no matter how the replicas
/// were scheduled onto worker threads. The virtual-cluster clock is
/// accounted with the same [`virtual_clock`] as the simulated backend.
pub struct PjrtExecutor {
    backend: EngineBackend,
    cost: CostModel,
    corpus: SyntheticCorpus,
    lora: ParamVector,
}

impl PjrtExecutor {
    pub fn new(engine: Engine, cost: CostModel, corpus: SyntheticCorpus) -> Self {
        let n = engine.manifest().lora_param_count;
        Self {
            backend: EngineBackend::Pjrt(engine),
            cost,
            corpus,
            lora: ParamVector::zeros(n),
        }
    }

    /// Build on the pure-Rust staged runtime instead of PJRT artifacts:
    /// multi-GPU replica configs execute for real (tp-sharded matmuls,
    /// pp-stage 1F1B pipeline) and every microbatch — multi-GPU included —
    /// emits a measured calibration observation.
    pub fn with_native(
        model: NativeModel,
        base: ParamVector,
        cost: CostModel,
        corpus: SyntheticCorpus,
    ) -> Result<Self> {
        if base.len() as u64 != model.base_param_count() {
            return Err(anyhow!(
                "base params {} != native spec {}",
                base.len(),
                model.base_param_count()
            ));
        }
        let n = model.lora_param_count();
        Ok(Self {
            backend: EngineBackend::Native {
                model: Arc::new(model),
                base: Arc::new(base),
            },
            cost,
            corpus,
            lora: ParamVector::zeros(n),
        })
    }

    /// The PJRT engine, when that's the backend (`None` for native).
    pub fn engine(&self) -> Option<&Engine> {
        match &self.backend {
            EngineBackend::Pjrt(e) => Some(e),
            EngineBackend::Native { .. } => None,
        }
    }

    pub fn engine_mut(&mut self) -> Option<&mut Engine> {
        match &mut self.backend {
            EngineBackend::Pjrt(e) => Some(e),
            EngineBackend::Native { .. } => None,
        }
    }

    /// Execution platform name (PJRT client platform, or `"native"`).
    pub fn platform(&self) -> String {
        match &self.backend {
            EngineBackend::Pjrt(e) => e.platform(),
            EngineBackend::Native { .. } => "native".to_string(),
        }
    }

    /// Microbatch shapes the backend can execute, ascending by seq.
    pub fn shapes(&self) -> Vec<(u64, u64)> {
        match &self.backend {
            EngineBackend::Pjrt(e) => e.shapes(),
            EngineBackend::Native { model, .. } => model.shapes(),
        }
    }

    /// Cost model accounting the virtual-cluster clock.
    pub fn cost(&self) -> &CostModel {
        &self.cost
    }

    /// Swap the virtual cluster's cost model (e.g. after planning a real
    /// deployment to account against).
    pub fn set_cost(&mut self, cost: CostModel) {
        self.cost = cost;
    }

    /// Snapshot the adapter vector the next `execute_step` runs with.
    pub fn set_lora(&mut self, lora: &ParamVector) {
        self.lora = lora.clone();
    }
}

/// Fold one microbatch's training output into a replica partial — the
/// identical accumulation for every backend path, so a backend swap can
/// never change the loss/gradient arithmetic.
fn accumulate(acc: &mut ReplicaPartial, out: &StepOutput, n_tasks: usize) {
    let w = out.tokens as f64;
    acc.loss_sum += out.loss as f64 * w;
    acc.tokens += w;
    for (g, gi) in acc.grad.iter_mut().zip(&out.grad) {
        *g += gi * out.tokens;
    }
    for t in 0..n_tasks {
        acc.task_loss[t] += out.task_loss[t] as f64;
        acc.task_tokens[t] += out.task_tokens[t] as f64;
    }
    acc.microbatches += 1;
}

impl ReplicaExecutor for PjrtExecutor {
    fn backend(&self) -> &'static str {
        match self.backend {
            EngineBackend::Pjrt(_) => "pjrt",
            EngineBackend::Native { .. } => "native",
        }
    }

    fn execute_step(&mut self, plan: &ExecutionPlan) -> Result<StepExecution> {
        let t0 = Stopwatch::start();
        let shapes = self.shapes();
        // materialize sequentially (deterministic corpus RNG order) ...
        let per_replica: Vec<(ParallelConfig, Vec<Microbatch>)> = plan
            .assignments
            .iter()
            .map(|a| (a.config, materialize_assignment(&mut self.corpus, &shapes, a)))
            .collect();

        let n_params = self.lora.len();
        let n_tasks = match &self.backend {
            EngineBackend::Pjrt(e) => e.manifest().model.n_tasks as usize,
            EngineBackend::Native { model, .. } => model.spec().n_tasks,
        };
        let backend = &self.backend;
        let lora = &self.lora;
        // ... then execute replicas concurrently, timing each microbatch
        // in situ: the (b, s, seconds) observations feed cost-model
        // calibration (`costmodel::calibrate`).
        //
        // PJRT backend: only single-GPU configs are recorded — the local
        // engine realizes no tp/pp parallelism, so a multi-GPU replica's
        // wall-clock there would be a whole-microbatch time, not the
        // per-*stage* `t(b,s)` the cost model fits (pp division and the
        // pipeline bubble would be double-counted). Native backend:
        // multi-GPU replicas run on the staged pipeline, whose per-mb
        // timings attribute tp comm and the bubble share explicitly, so
        // every config observes.
        let partials: Vec<Result<ReplicaPartial>> = par_map(per_replica, |(config, mbs)| {
            let mut acc = ReplicaPartial::empty(n_params, n_tasks);
            match backend {
                EngineBackend::Pjrt(engine) => {
                    let observe = config.n() == 1;
                    for mb in mbs {
                        let mb_t0 = Stopwatch::start();
                        let out =
                            engine.train_step(mb.shape, lora, &mb.tokens, &mb.seg_ids)?;
                        if observe {
                            acc.observations.push((
                                *config,
                                Observation::new(
                                    mb.shape.0,
                                    mb.shape.1,
                                    mb_t0.elapsed_secs(),
                                ),
                            ));
                        }
                        accumulate(&mut acc, &out, n_tasks);
                    }
                }
                EngineBackend::Native { model, base } if config.n() == 1 => {
                    for mb in mbs {
                        let mb_t0 = Stopwatch::start();
                        let out = model.train_step(
                            base,
                            lora,
                            mb.shape,
                            &mb.tokens,
                            &mb.seg_ids,
                        )?;
                        acc.observations.push((
                            *config,
                            Observation::new(mb.shape.0, mb.shape.1, mb_t0.elapsed_secs()),
                        ));
                        accumulate(&mut acc, &out, n_tasks);
                    }
                }
                EngineBackend::Native { model, base } => {
                    let staged = StagedEngine::new(
                        Arc::clone(model),
                        Arc::clone(base),
                        config.tp as usize,
                        config.pp as usize,
                    )?;
                    let stage_mbs: Vec<StageMb> = mbs
                        .iter()
                        .map(|mb| StageMb {
                            shape: mb.shape,
                            tokens: mb.tokens.clone(),
                            seg_ids: mb.seg_ids.clone(),
                        })
                        .collect();
                    for (mb, (out, timing)) in
                        mbs.iter().zip(staged.run(lora, &stage_mbs)?)
                    {
                        acc.observations.push((
                            *config,
                            Observation::with_overheads(
                                mb.shape.0,
                                mb.shape.1,
                                timing.seconds,
                                timing.comm,
                                timing.bubble,
                            ),
                        ));
                        accumulate(&mut acc, &out, n_tasks);
                    }
                }
            }
            Ok(acc)
        });
        let mut ordered = Vec::with_capacity(partials.len());
        for p in partials {
            ordered.push(p?);
        }
        let total = tree_reduce(ordered, ReplicaPartial::merge)
            .unwrap_or_else(|| ReplicaPartial::empty(n_params, n_tasks));
        if total.microbatches == 0 {
            return Err(anyhow!("execution plan produced no microbatches"));
        }

        let (replica_seconds, step_time) = virtual_clock(&self.cost, plan);
        Ok(StepExecution {
            replica_seconds,
            step_time,
            wall_seconds: t0.elapsed_secs(),
            observations: total.observations,
            train: Some(TrainOutput {
                grad: total.grad,
                loss_sum: total.loss_sum,
                tokens: total.tokens,
                task_loss: total.task_loss,
                task_tokens: total.task_tokens,
                microbatches: total.microbatches,
            }),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParallelConfig;
    use crate::costmodel::BucketLoad;
    use crate::data::Sequence;

    fn assignment(lens: &[(u32, u32)], padded_len: u64) -> ReplicaAssignment {
        let seqs: Vec<Sequence> =
            lens.iter().map(|&(task, len)| Sequence { task, len }).collect();
        ReplicaAssignment {
            replica: 0,
            group: 0,
            config: ParallelConfig::new(1, 1),
            loads: vec![BucketLoad { count: seqs.len() as u64, padded_len }],
            sequences: vec![seqs],
        }
    }

    #[test]
    fn partial_chunks_pad_with_pad_rows_not_duplicates() {
        // regression: the pre-exec trainer filled a short microbatch by
        // repeating the last real sequence, double-counting its gradient;
        // padding must be true PAD rows that contribute zero targets
        let mut corpus = SyntheticCorpus::new(512, 3, 42);
        let shapes = [(2u64, 32u64)];
        let a = assignment(&[(0, 10), (1, 20), (2, 15)], 32);
        let mbs = materialize_assignment(&mut corpus, &shapes, &a);
        assert_eq!(mbs.len(), 2, "3 sequences at b=2 -> 2 microbatches");
        assert_eq!(mbs[0].real_rows, 2);
        assert_eq!(mbs[1].real_rows, 1);
        for mb in &mbs {
            assert_eq!(mb.tokens.len(), 2 * 32);
            assert_eq!(mb.seg_ids.len(), 2);
            assert!(mb.seg_ids.windows(2).all(|w| w[0] <= w[1]));
            // pad rows are all-PAD
            for row in mb.real_rows..2 {
                assert!(
                    mb.tokens[row * 32..(row + 1) * 32].iter().all(|&t| t == 0),
                    "pad row has real tokens"
                );
            }
        }
        // gradient-weight proxy: per-task non-pad token exposure must equal
        // each sequence's length exactly once (duplicate-padding doubled
        // the last sequence's task here)
        let mut per_task = [0usize; 3];
        for mb in &mbs {
            for row in 0..mb.real_rows {
                let task = mb.seg_ids[row] as usize;
                per_task[task] += mb.tokens[row * 32..(row + 1) * 32]
                    .iter()
                    .filter(|&&t| t != 0)
                    .count();
            }
        }
        assert_eq!(per_task, [10, 20, 15]);
    }

    #[test]
    fn full_chunks_have_no_pad_rows() {
        let mut corpus = SyntheticCorpus::new(512, 2, 7);
        let shapes = [(2u64, 16u64)];
        let a = assignment(&[(0, 8), (1, 8), (0, 8), (1, 8)], 16);
        let mbs = materialize_assignment(&mut corpus, &shapes, &a);
        assert_eq!(mbs.len(), 2);
        assert!(mbs.iter().all(|mb| mb.real_rows == 2));
    }

    #[test]
    fn load_maps_to_smallest_covering_shape() {
        let mut corpus = SyntheticCorpus::new(512, 2, 9);
        let shapes = [(8u64, 16u64), (4, 64), (2, 128)];
        let a = assignment(&[(0, 20), (1, 60)], 64);
        let mbs = materialize_assignment(&mut corpus, &shapes, &a);
        assert_eq!(mbs.len(), 1);
        assert_eq!(mbs[0].shape, (4, 64));
        assert_eq!(mbs[0].real_rows, 2);
        // over-long buckets fall back to the largest shape (truncating)
        let b = assignment(&[(0, 300)], 4096);
        let mbs = materialize_assignment(&mut corpus, &shapes, &b);
        assert_eq!(mbs[0].shape, (2, 128));
        assert!(mbs[0].tokens[..128].iter().all(|&t| t != 0));
    }

    #[test]
    fn empty_loads_are_skipped() {
        let mut corpus = SyntheticCorpus::new(512, 2, 3);
        let shapes = [(2u64, 16u64)];
        let a = ReplicaAssignment {
            replica: 0,
            group: 0,
            config: ParallelConfig::new(1, 1),
            loads: vec![BucketLoad { count: 0, padded_len: 16 }],
            sequences: vec![Vec::new()],
        };
        assert!(materialize_assignment(&mut corpus, &shapes, &a).is_empty());
    }
}
