//! A deployment-swappable simulated training loop.
//!
//! [`crate::coordinator::scheduler::Scheduler`] borrows one fixed
//! [`DeploymentPlan`] for its whole lifetime — the right shape for the
//! paper-figure benches, where the plan never changes, but unusable by a
//! serving runtime whose plan is *replaced* mid-run. [`SimTrainLoop`] owns
//! its plan and task set, so the runtime can [`SimTrainLoop::swap`] both at
//! a step boundary (the paper's redeploy point: adapters checkpointed, the
//! joint task restarted under the new plan) while the shared cost-table
//! LRU carries across deployments — a boundary vector that returns after a
//! redeploy hits the cache instead of rebuilding.

use std::sync::Arc;

use super::{ExecutionPlan, ReplicaExecutor, SimExecutor};
use crate::config::{ParallelConfig, TaskSet};
use crate::coordinator::bucketing::{bucketize, BucketingOptions};
use crate::coordinator::dispatcher::DispatchPolicy;
use crate::coordinator::planner::DeploymentPlan;
use crate::costmodel::{CostModel, CostTable, CostTables};
use crate::data::MultiTaskSampler;
use crate::util::clock::Stopwatch;

/// One executed simulated step.
#[derive(Debug, Clone, Copy)]
pub struct SimStep {
    /// Virtual-cluster step wall-clock (max replica time + LoRA sync).
    pub step_time: f64,
    /// `gpus_used × step_time` — the paper's headline accounting.
    pub gpu_seconds: f64,
    /// Table (re)build + dispatch-solve host wall-clock for this step.
    pub solve_seconds: f64,
}

/// Simulated joint-FT training under a swappable deployment plan.
pub struct SimTrainLoop<'a> {
    cost: &'a CostModel,
    plan: DeploymentPlan,
    tasks: TaskSet,
    sampler: MultiTaskSampler,
    policy: DispatchPolicy,
    bucketing: BucketingOptions,
    /// Shared cost-table LRU (typically the planning session's).
    tables: CostTables,
    /// Current step's table (skips the cache lock while consecutive
    /// batches land on the same boundaries — the common case).
    table: Option<Arc<CostTable>>,
    exec: SimExecutor<'a>,
    /// Steps executed under the *current* deployment (resets on swap).
    epoch_steps: u64,
    /// Steps executed across all deployments.
    total_steps: u64,
}

impl<'a> SimTrainLoop<'a> {
    pub fn new(
        cost: &'a CostModel,
        plan: DeploymentPlan,
        tasks: TaskSet,
        seed: u64,
        tables: CostTables,
    ) -> Self {
        Self {
            sampler: MultiTaskSampler::new(&tasks, seed),
            cost,
            plan,
            tasks,
            policy: DispatchPolicy::Balanced,
            bucketing: BucketingOptions::default(),
            tables,
            table: None,
            exec: SimExecutor::new(cost),
            epoch_steps: 0,
            total_steps: 0,
        }
    }

    pub fn plan(&self) -> &DeploymentPlan {
        &self.plan
    }

    pub fn tasks(&self) -> &TaskSet {
        &self.tasks
    }

    /// Steps executed under the current deployment.
    pub fn epoch_steps(&self) -> u64 {
        self.epoch_steps
    }

    /// Steps executed across all deployments this loop has run.
    pub fn total_steps(&self) -> u64 {
        self.total_steps
    }

    /// Swap deployment plan and task set at a step boundary. The sampler
    /// restarts for the new task set (deterministic per `seed`); the
    /// cost-table LRU carries over, so returning boundary vectors hit.
    pub fn swap(&mut self, plan: DeploymentPlan, tasks: TaskSet, seed: u64) {
        self.sampler = MultiTaskSampler::new(&tasks, seed);
        self.plan = plan;
        self.tasks = tasks;
        self.table = None;
        self.epoch_steps = 0;
    }

    /// Execute one simulated step: sample the fused batch, bucketize,
    /// solve the MINMAX dispatch and advance the cost-model clock. `None`
    /// when the loop has no tasks or the deployment cannot serve the
    /// sampled batch.
    pub fn step(&mut self) -> Option<SimStep> {
        if self.tasks.is_empty() || self.plan.groups.is_empty() {
            return None;
        }
        let batch = self.sampler.next_batch();
        let lengths = batch.lengths();
        let buckets = bucketize(&lengths, &self.bucketing);

        let t0 = Stopwatch::start();
        if self.table.as_ref().map_or(true, |t| !t.covers(&buckets.boundaries)) {
            let cfgs: Vec<ParallelConfig> =
                self.plan.groups.iter().map(|&(c, _)| c).collect();
            self.table =
                Some(self.tables.get_or_build(self.cost, &cfgs, &buckets.boundaries));
        }
        let table_seconds = t0.elapsed_secs();
        let eplan = ExecutionPlan::build(
            self.cost,
            &self.plan,
            self.table.clone(),
            batch,
            buckets,
            self.policy,
        )?;
        let solve_seconds = table_seconds + eplan.solve_seconds;
        let out = self.exec.execute_step(&eplan).ok()?;
        self.epoch_steps += 1;
        self.total_steps += 1;
        Some(SimStep {
            step_time: out.step_time,
            gpu_seconds: self.plan.gpus_used() as f64 * out.step_time,
            solve_seconds,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelDesc;
    use crate::coordinator::planner::{Planner, PlannerOptions};
    use crate::coordinator::scheduler::{Scheduler, SchedulerOptions};

    fn world() -> (CostModel, ClusterSpec, TaskSet) {
        let cluster = ClusterSpec::a100_40g(16);
        let cost = CostModel::calibrated(&ModelDesc::llama2_7b(), &cluster);
        let tasks = TaskSet::paper_7b_subset();
        (cost, cluster, tasks)
    }

    #[test]
    fn steps_match_scheduler_bitwise() {
        // the loop is the scheduler's step pipeline behind a swappable
        // plan: same seed + same plan must produce bit-identical clocks
        let (cost, cluster, tasks) = world();
        let plan = Planner::new(&cost, &cluster)
            .plan(&tasks, PlannerOptions::default())
            .unwrap();
        let opts = SchedulerOptions::default();
        let mut sched = Scheduler::new(&cost, &plan, &tasks, opts.clone());
        let mut tl = SimTrainLoop::new(
            &cost,
            plan.clone(),
            tasks.clone(),
            opts.seed,
            CostTables::default(),
        );
        for step in 0..8 {
            let a = sched.step().unwrap();
            let b = tl.step().unwrap();
            assert_eq!(
                a.step_time.to_bits(),
                b.step_time.to_bits(),
                "step {step}: loop diverged from scheduler"
            );
            assert_eq!(a.gpu_seconds.to_bits(), b.gpu_seconds.to_bits(), "step {step}");
        }
        assert_eq!(tl.total_steps(), 8);
    }

    #[test]
    fn swap_changes_deployment_at_step_boundary() {
        let (cost, cluster, tasks) = world();
        let planner = Planner::new(&cost, &cluster);
        let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
        let mut tl = SimTrainLoop::new(
            &cost,
            plan.clone(),
            tasks.clone(),
            7,
            CostTables::default(),
        );
        for _ in 0..3 {
            tl.step().unwrap();
        }
        assert_eq!(tl.epoch_steps(), 3);
        // shrink to a two-task world and its own plan
        let small = TaskSet::new(tasks.tasks[..2].to_vec());
        let plan2 = planner.plan(&small, PlannerOptions::default()).unwrap();
        tl.swap(plan2.clone(), small.clone(), 11);
        assert_eq!(tl.epoch_steps(), 0);
        assert_eq!(tl.plan().groups, plan2.groups);
        assert_eq!(tl.tasks().len(), 2);
        let s = tl.step().unwrap();
        assert!(s.step_time > 0.0);
        // post-swap steps are exactly a fresh loop over the new world
        let mut fresh =
            SimTrainLoop::new(&cost, plan2, small, 11, CostTables::default());
        let f = fresh.step().unwrap();
        assert_eq!(s.step_time.to_bits(), f.step_time.to_bits());
        assert_eq!(tl.total_steps(), 4);
    }

    #[test]
    fn empty_tasks_or_plan_yield_no_step() {
        let (cost, cluster, tasks) = world();
        let plan = Planner::new(&cost, &cluster)
            .plan(&tasks, PlannerOptions::default())
            .unwrap();
        let mut empty_tasks =
            SimTrainLoop::new(&cost, plan, TaskSet::default(), 1, CostTables::default());
        assert!(empty_tasks.step().is_none());
        let empty_plan = DeploymentPlan {
            groups: Vec::new(),
            n_tasks: tasks.len() as u32,
            expected_step_time: 0.0,
        };
        let mut no_plan =
            SimTrainLoop::new(&cost, empty_plan, tasks, 1, CostTables::default());
        assert!(no_plan.step().is_none());
    }
}
