//! Decomposed microbatch timing (exposed for benches / the Table 3 study).

/// Breakdown of one chunk's stage time — useful for the ablation benches
//  and for explaining *why* a configuration wins at a sequence length.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MicrobatchTime {
    pub compute: f64,
    pub tp_comm: f64,
    pub pp_comm: f64,
    pub overhead: f64,
}

impl MicrobatchTime {
    pub fn total(&self) -> f64 {
        self.compute + self.tp_comm + self.pp_comm + self.overhead
    }

    /// Fraction of the stage time spent communicating.
    pub fn comm_fraction(&self) -> f64 {
        let t = self.total();
        if t > 0.0 {
            (self.tp_comm + self.pp_comm) / t
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let m = MicrobatchTime { compute: 1.0, tp_comm: 0.5, pp_comm: 0.25, overhead: 0.25 };
        assert_eq!(m.total(), 2.0);
        assert!((m.comm_fraction() - 0.375).abs() < 1e-12);
    }
}
