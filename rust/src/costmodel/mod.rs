//! Cost model of FT replicas (paper §2.2 + Appendix D).
//!
//! Everything LobRA decides — which parallel configurations to deploy, how
//! many replicas of each, and where each training sequence goes — is driven
//! by two functions of a parallel configuration `S = ⟨TP, PP⟩`:
//!
//! * `max_chunk_tokens(S)` — the memory model: how many (padded) tokens one
//!   chunk (micro-batch) may hold without OOM. Memory is linear in the
//!   summed chunk length (paper refs [8, 9, 73]), so this is a single
//!   capacity number per configuration.
//! * `replica_time({d_j}; S)` — Eq. 10 (no PP) / Eq. 12 (variable-length
//!   1F1B PP): the per-step running time of one replica given `d_j`
//!   sequences in bucket `j`.
//!
//! Out of the box the per-microbatch time `t(b, s)` is built from first
//! principles (FLOP count over MXU rate + Megatron-style TP all-reduce
//! volume + PP p2p), with constants calibrated so the resulting throughput
//! table reproduces the *partial order* of the paper's Table 3
//! (Observation 1) — see `tests` and `rust/benches/table3_throughput.rs`.
//! When a measured [`CalibrationProfile`] is attached
//! ([`CostModel::from_profile`]), configurations it covers read `t(b, s)`
//! from the fitted coefficients instead — the paper's actual
//! offline-profiling methodology, fed in situ by the executors (see
//! [`calibrate`]); the memory model stays analytic either way.

pub mod calibrate;
mod replica;
mod table;
mod timing;

pub use calibrate::{
    load_profile_or_analytic, CalibrationProfile, CalibrationStore, ConfigCalibration,
    FittedCost, Observation,
};
pub use replica::{BucketLoad, ChunkPlan};
pub use table::{
    cost_fingerprint, structural_hash, world_fingerprint, CostTable, CostTableKey,
    CostTableLru, CostTables,
};
pub(crate) use table::fnv1a;
pub use timing::MicrobatchTime;

use std::sync::Arc;

use crate::cluster::{ClusterSpec, CommModel};
use crate::config::{ModelDesc, ParallelConfig};
use anyhow::{anyhow, Result};

/// Fixed per-GPU memory overhead (runtime, fragmentation, comm buffers), GiB.
const MEM_OVERHEAD_GIB: f64 = 4.0;
/// Activation bytes per token ≈ C_ACT · L · d · bytes; C_ACT calibrated so a
/// 7B model on one A100-40G supports ≈2K tokens (paper Figure 2 annotation).
const C_ACT: f64 = 40.0;
/// Fixed overhead per chunk (kernel launches, optimizer step slice), sec.
const CHUNK_OVERHEAD: f64 = 2e-3;
/// Fixed per-step overhead per replica (data loading, bookkeeping), sec.
const STEP_OVERHEAD: f64 = 10e-3;

/// Profiled-cost oracle for one (model, cluster) pair.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub model: ModelDesc,
    pub cluster: ClusterSpec,
    comm: CommModel,
    /// Measured per-config `t(b,s)` overriding the analytic timing model
    /// for the configurations it covers. Part of the cost identity: folded
    /// into [`cost_fingerprint`] so cost tables built from different
    /// profile generations never alias.
    profile: Option<Arc<CalibrationProfile>>,
}

impl CostModel {
    /// Build the calibrated cost model (paper: offline profiling; here:
    /// analytic model with calibrated constants, see module docs).
    pub fn calibrated(model: &ModelDesc, cluster: &ClusterSpec) -> Self {
        Self {
            model: model.clone(),
            cluster: cluster.clone(),
            comm: CommModel::new(cluster),
            profile: None,
        }
    }

    /// Build a cost model that plans against *measured* microbatch times:
    /// configurations covered by `profile` read `t(b,s)` from the fitted
    /// coefficients, everything else (and the memory model) stays
    /// analytic. Fails when the profile was measured on a different
    /// `(model, cluster)` world or fitted nothing.
    pub fn from_profile(
        model: &ModelDesc,
        cluster: &ClusterSpec,
        profile: CalibrationProfile,
    ) -> Result<Self> {
        let want_device = cluster.device.fingerprint();
        if profile.device_fingerprint() != want_device {
            return Err(anyhow!(
                "calibration profile was measured on a different device generation \
                 (profile device {:016x}, this pool's {} is {:016x}) — in a mixed \
                 fleet each pool calibrates separately",
                profile.device_fingerprint(),
                cluster.device.name,
                want_device
            ));
        }
        let want = world_fingerprint(model, cluster);
        if profile.fingerprint() != want {
            return Err(anyhow!(
                "calibration profile was measured on a different (model, cluster) world \
                 (profile {:016x}, this world {:016x})",
                profile.fingerprint(),
                want
            ));
        }
        if profile.is_empty() {
            return Err(anyhow!(
                "calibration profile holds no fitted configuration — nothing to plan from"
            ));
        }
        let mut cost = Self::calibrated(model, cluster);
        cost.profile = Some(Arc::new(profile));
        Ok(cost)
    }

    /// The attached measured profile, if any.
    pub fn profile(&self) -> Option<&CalibrationProfile> {
        self.profile.as_deref()
    }

    /// Whether timing comes from measured coefficients (for any config).
    pub fn is_profiled(&self) -> bool {
        self.profile.is_some()
    }

    pub fn comm(&self) -> &CommModel {
        &self.comm
    }

    // --- memory model -----------------------------------------------------------

    /// Activation bytes per token per GPU under `cfg`.
    ///
    /// TP shards activations; PP does *not* reduce the per-GPU activation
    /// footprint because 1F1B keeps ~`pp` microbatches in flight (each stage
    /// holds `L/pp` layers × `pp` live chunks).
    fn act_bytes_per_token(&self, cfg: ParallelConfig) -> f64 {
        C_ACT * self.model.n_layers as f64 * self.model.d_model as f64
            * self.model.weight_bytes as f64
            / cfg.tp as f64
    }

    /// Max summed tokens per chunk (micro-batch) without OOM; 0 = infeasible.
    pub fn max_chunk_tokens(&self, cfg: ParallelConfig) -> u64 {
        let mem = self.cluster.device.gpu_mem_gib * (1u64 << 30) as f64;
        let weights = self.model.weight_bytes_per_gpu(cfg.tp, cfg.pp) as f64;
        let free = mem - weights - MEM_OVERHEAD_GIB * (1u64 << 30) as f64;
        if free <= 0.0 {
            return 0;
        }
        (free / self.act_bytes_per_token(cfg)) as u64
    }

    /// Longest single sequence `cfg` can process (one sequence per chunk).
    pub fn max_seq_len(&self, cfg: ParallelConfig) -> u64 {
        self.max_chunk_tokens(cfg)
    }

    /// Whether `cfg` can hold the model at all on this cluster.
    pub fn feasible(&self, cfg: ParallelConfig) -> bool {
        cfg.n() <= self.cluster.n_gpus && self.max_chunk_tokens(cfg) >= 64
    }

    // --- timing model -----------------------------------------------------------

    /// Fwd+bwd FLOPs for a microbatch of `b` sequences of padded length `s`.
    fn flops(&self, b: u64, s: u64) -> f64 {
        let dense = 6.0 * (self.model.params - self.model.vocab * self.model.d_model) as f64
            * (b * s) as f64;
        let attn = 12.0
            * self.model.n_layers as f64
            * self.model.d_model as f64
            * b as f64
            * (s as f64) * (s as f64);
        // LM head (often dominant for small models).
        let head = 6.0 * (self.model.vocab * self.model.d_model) as f64 * (b * s) as f64;
        dense + attn + head
    }

    /// Analytic decomposition of one chunk's stage time into compute, TP
    /// collectives, PP p2p and fixed launch overhead. Always analytic,
    /// independent of any attached profile: the executors use it to
    /// attribute measured wall time (so the calibration fit regresses
    /// compute, not compute + comm), and [`t_microbatch`](Self::t_microbatch)
    /// re-adds these communication terms on top of a measured compute fit.
    pub fn microbatch_breakdown(&self, cfg: ParallelConfig, b: u64, s: u64) -> MicrobatchTime {
        if b == 0 {
            return MicrobatchTime { compute: 0.0, tp_comm: 0.0, pp_comm: 0.0, overhead: 0.0 };
        }
        let compute = self.flops(b, s)
            / cfg.pp as f64
            / (cfg.tp as f64 * self.cluster.effective_flops());
        // Megatron TP: ~8 collectives of b·s·d activation bytes per layer
        // (2 fwd + 2 bwd on attention + MLP, doubled by activation
        // recomputation in the backward), over the stage's L/pp layers.
        let tp_comm = if cfg.tp > 1 {
            let bytes = (b * s * self.model.d_model * self.model.weight_bytes) as f64;
            let per_layer = 8.0 * self.comm.tp_allreduce(bytes, cfg.tp);
            per_layer * self.model.n_layers as f64 / cfg.pp as f64
        } else {
            0.0
        };
        // PP p2p of boundary activations (fwd + bwd).
        let pp_comm = if cfg.pp > 1 {
            let bytes = (b * s * self.model.d_model * self.model.weight_bytes) as f64
                / cfg.tp as f64;
            2.0 * self.comm.pp_p2p(bytes, cfg.tp)
        } else {
            0.0
        };
        MicrobatchTime { compute, tp_comm, pp_comm, overhead: CHUNK_OVERHEAD }
    }

    /// Time of one chunk through one pipeline *stage* (the `t(b,s)` of
    /// Eq. 11/12): compute + TP collectives + PP p2p, per stage. With a
    /// profiled configuration the measured *compute* fit replaces the
    /// analytic compute + overhead (measurements subtract their comm and
    /// bubble attribution before fitting), and the analytic communication
    /// terms are re-added on top.
    pub fn t_microbatch(&self, cfg: ParallelConfig, b: u64, s: u64) -> f64 {
        if b == 0 {
            return 0.0;
        }
        let t = self.microbatch_breakdown(cfg, b, s);
        if let Some(f) = self.profile.as_ref().and_then(|p| p.fitted_for(cfg)) {
            // a noisy fit can dip below zero at tiny shapes; time is not
            // allowed to
            return f.predict(b, s).max(0.0) + t.tp_comm + t.pp_comm;
        }
        t.compute + t.tp_comm + t.pp_comm + t.overhead
    }

    /// Throughput in tokens / GPU / second for chunks of shape (b, s) — the
    /// quantity tabulated in the paper's Table 3.
    pub fn throughput(&self, cfg: ParallelConfig, b: u64, s: u64) -> f64 {
        let stage_t = self.t_microbatch(cfg, b, s);
        // Steady-state pipeline: one chunk completes per stage time.
        (b * s) as f64 / (stage_t * cfg.n() as f64)
    }

    /// Per-sequence marginal cost of a bucket-`j` sequence (padded to `s_j`)
    /// on `cfg` — the linear coefficient `c_{ij}` the dispatch ILP uses.
    pub fn per_seq_cost(&self, cfg: ParallelConfig, s: u64) -> f64 {
        let cap = self.max_chunk_tokens(cfg);
        if cap < s {
            return f64::INFINITY;
        }
        let b = (cap / s).max(1);
        self.t_microbatch(cfg, b, s) / b as f64
    }

    /// Chunking of `d` sequences of padded length `s`: full chunks of
    /// `b = ⌊cap/s⌋` plus a remainder chunk (Eq. 10's m·t(b,s) + t(r,s)).
    pub fn chunks_for(&self, cfg: ParallelConfig, d: u64, s: u64) -> ChunkPlan {
        let cap = self.max_chunk_tokens(cfg);
        let b = (cap / s.max(1)).max(1);
        ChunkPlan { per_chunk: b, full_chunks: d / b, remainder: d % b }
    }

    /// Eq. 10 / Eq. 12: replica step time given per-bucket loads.
    ///
    /// `loads` = (d_j, s_j) pairs: d_j sequences padded to s_j. Compute time
    /// sums all chunks across buckets; with PP, the bubble adds
    /// `(pp−1) × max_j t(chunk_j)` (descending-time chunk ordering — the
    /// paper's phased critical-path estimate).
    pub fn replica_time(&self, cfg: ParallelConfig, loads: &[BucketLoad]) -> f64 {
        let mut compute = 0.0;
        let mut max_chunk_t: f64 = 0.0;
        let mut any = false;
        for &BucketLoad { count: d, padded_len: s } in loads {
            if d == 0 {
                continue;
            }
            any = true;
            let plan = self.chunks_for(cfg, d, s);
            let t_full = self.t_microbatch(cfg, plan.per_chunk, s);
            compute += plan.full_chunks as f64 * t_full;
            if plan.full_chunks > 0 {
                max_chunk_t = max_chunk_t.max(t_full);
            }
            if plan.remainder > 0 {
                let t_rem = self.t_microbatch(cfg, plan.remainder, s);
                compute += t_rem;
                max_chunk_t = max_chunk_t.max(t_rem);
            }
        }
        if !any {
            return 0.0;
        }
        let bubble = (cfg.pp as f64 - 1.0) * max_chunk_t;
        compute + bubble + STEP_OVERHEAD
    }

    /// Per-step LoRA gradient synchronization across `n_replicas` replicas.
    pub fn sync_time(&self, n_replicas: u32, n_tasks: u32) -> f64 {
        if n_replicas <= 1 {
            return 0.0;
        }
        let lora_bytes =
            (self.model.lora_params_per_task() * n_tasks as u64 * 4) as f64;
        self.comm.dp_allreduce(lora_bytes, n_replicas)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm7b_16() -> CostModel {
        CostModel::calibrated(&ModelDesc::llama2_7b(), &ClusterSpec::a100_40g(16))
    }

    fn cfg(tp: u32, pp: u32) -> ParallelConfig {
        ParallelConfig::new(tp, pp)
    }

    #[test]
    fn figure2_memory_annotation() {
        // Fig. 2: 7B on A100-40G needs 1/2/4/8 GPUs for ≈2K/4K/8K/16K.
        let cm = cm7b_16();
        let m1 = cm.max_seq_len(cfg(1, 1));
        assert!((1_500..3_500).contains(&m1), "1 GPU: {m1}");
        let m2 = cm.max_seq_len(cfg(2, 1));
        assert!((3_000..7_000).contains(&m2), "2 GPUs: {m2}");
        let m8 = cm.max_seq_len(cfg(8, 1));
        assert!(m8 >= 14_000, "8 GPUs: {m8}");
    }

    #[test]
    fn pp_does_not_extend_max_length_like_tp() {
        // Table 3: ⟨1,8⟩ OOMs at 8K while ⟨8,1⟩ reaches 16K.
        let cm = cm7b_16();
        assert!(cm.max_seq_len(cfg(8, 1)) > 2 * cm.max_seq_len(cfg(1, 8)));
    }

    #[test]
    fn table3_partial_order() {
        // At n=8 GPUs and 2K: thrpt ⟨1,8⟩ > ⟨2,4⟩ > ⟨4,2⟩ > ⟨8,1⟩.
        let cm = cm7b_16();
        let t = |c: ParallelConfig| cm.throughput(c, 4, 2048);
        assert!(t(cfg(1, 8)) > t(cfg(2, 4)), "1,8 vs 2,4");
        assert!(t(cfg(2, 4)) > t(cfg(4, 2)), "2,4 vs 4,2");
        assert!(t(cfg(4, 2)) > t(cfg(8, 1)), "4,2 vs 8,1");
        // Fewer GPUs per replica is more efficient: ⟨1,1⟩ beats all n=8.
        assert!(cm.throughput(cfg(1, 1), 1, 2048) > t(cfg(1, 8)));
    }

    #[test]
    fn observation1_partial_order_stability() {
        // Obs. 1: if S_a beats S_b at s0, it also wins at shorter s with
        // b·s = s0 (same token budget).
        let cm = cm7b_16();
        let pairs = [(cfg(1, 8), cfg(8, 1)), (cfg(2, 4), cfg(4, 2))];
        for (a, b) in pairs {
            let wins_at = |s: u64, bsz: u64| {
                cm.throughput(a, bsz, s) > cm.throughput(b, bsz, s)
            };
            assert!(wins_at(8192, 1) || !wins_at(2048, 4) || true);
            // explicit: winner at 8K stays winner at 2K with 4x batch
            if wins_at(8192, 1) {
                assert!(wins_at(2048, 4), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn replica_time_monotone_in_load() {
        let cm = cm7b_16();
        let c = cfg(2, 1);
        let t1 = cm.replica_time(c, &[BucketLoad { count: 16, padded_len: 512 }]);
        let t2 = cm.replica_time(c, &[BucketLoad { count: 32, padded_len: 512 }]);
        assert!(t2 > t1);
    }

    #[test]
    fn replica_time_empty_is_zero() {
        let cm = cm7b_16();
        assert_eq!(cm.replica_time(cfg(1, 1), &[]), 0.0);
        assert_eq!(
            cm.replica_time(cfg(1, 1), &[BucketLoad { count: 0, padded_len: 512 }]),
            0.0
        );
    }

    #[test]
    fn pipeline_bubble_increases_time() {
        let cm = cm7b_16();
        let loads = [BucketLoad { count: 8, padded_len: 1024 }];
        // Same GPUs, more stages => bubble overhead exists.
        let t_pp = cm.replica_time(cfg(1, 4), &loads);
        let compute_only: f64 = {
            let plan = cm.chunks_for(cfg(1, 4), 8, 1024);
            plan.full_chunks as f64 * cm.t_microbatch(cfg(1, 4), plan.per_chunk, 1024)
                + if plan.remainder > 0 {
                    cm.t_microbatch(cfg(1, 4), plan.remainder, 1024)
                } else {
                    0.0
                }
        };
        assert!(t_pp > compute_only);
    }

    #[test]
    fn per_seq_cost_infinite_when_oom() {
        let cm = cm7b_16();
        assert!(cm.per_seq_cost(cfg(1, 1), 16384).is_infinite());
        assert!(cm.per_seq_cost(cfg(8, 1), 16384).is_finite());
    }

    #[test]
    fn infeasible_configs_detected() {
        // 70B on A100-40G: a single GPU cannot hold the weights.
        let cm = CostModel::calibrated(&ModelDesc::llama2_70b(), &ClusterSpec::a100_40g(16));
        assert!(!cm.feasible(cfg(1, 1)));
        let cm64 = CostModel::calibrated(&ModelDesc::llama2_70b(), &ClusterSpec::a800_80g(64));
        assert!(cm64.feasible(cfg(8, 1)));
    }

    #[test]
    fn profiled_config_overrides_analytic_timing_only() {
        let model = ModelDesc::llama2_7b();
        let cluster = ClusterSpec::a100_40g(16);
        let analytic = CostModel::calibrated(&model, &cluster);
        let c = cfg(2, 1);
        // synthetic measured world running exactly 2× slower than analytic;
        // the observations attribute the analytic TP comm so the fit
        // regresses compute and the profiled model re-adds comm on top
        let mut store = CalibrationStore::for_world(&model, &cluster);
        for &(b, s) in &[(16u64, 512u64), (4, 2048), (1, 8192), (8, 512), (2, 2048)] {
            let comm = analytic.microbatch_breakdown(c, b, s).tp_comm;
            store.record_observation(
                c,
                Observation::with_overheads(b, s, 2.0 * analytic.t_microbatch(c, b, s), comm, 0.0),
            );
        }
        let profiled = CostModel::from_profile(&model, &cluster, store.profile()).unwrap();
        assert!(profiled.is_profiled());
        let got = profiled.t_microbatch(c, 4, 2048);
        let want = 2.0 * analytic.t_microbatch(c, 4, 2048);
        assert!((got - want).abs() / want < 1e-3, "{got} vs {want}");
        // unprofiled configurations and the memory model stay analytic
        let other = cfg(8, 1);
        assert_eq!(
            profiled.t_microbatch(other, 4, 2048).to_bits(),
            analytic.t_microbatch(other, 4, 2048).to_bits()
        );
        assert_eq!(profiled.max_chunk_tokens(c), analytic.max_chunk_tokens(c));
        // a profile from another world never attaches
        let other_world = CalibrationStore::for_world(&ModelDesc::llama2_70b(), &cluster);
        assert!(CostModel::from_profile(
            &model,
            &cluster,
            other_world.clone().profile()
        )
        .is_err());
        // ... and an empty profile is rejected too
        assert!(CostModel::from_profile(
            &ModelDesc::llama2_70b(),
            &cluster,
            other_world.clone().profile()
        )
        .is_err());
    }

    #[test]
    fn profile_from_other_device_pool_rejected() {
        // mixed fleet (a100:16 + h100:8): one pool's measured fits must
        // never serve another pool's planning, and the error names the
        // device mismatch rather than a generic world mismatch
        let model = ModelDesc::llama2_7b();
        let a100 = ClusterSpec::a100_40g(16);
        let h100 = ClusterSpec::h100_80g(16);
        let analytic = CostModel::calibrated(&model, &a100);
        let c = cfg(2, 1);
        let mut store = CalibrationStore::for_world(&model, &a100);
        for &(b, s) in &[(16u64, 512u64), (4, 2048), (1, 8192), (8, 512), (2, 2048)] {
            store.record(c, b, s, analytic.t_microbatch(c, b, s));
        }
        let err = CostModel::from_profile(&model, &h100, store.profile()).unwrap_err();
        assert!(err.to_string().contains("device generation"), "{err}");
    }

    #[test]
    fn breakdown_total_matches_t_microbatch_bitwise() {
        let cm = cm7b_16();
        for &c in &[cfg(1, 1), cfg(2, 1), cfg(1, 4), cfg(2, 4), cfg(8, 2)] {
            for &(b, s) in &[(1u64, 512u64), (4, 2048), (16, 128)] {
                let t = cm.microbatch_breakdown(c, b, s);
                assert_eq!(
                    (t.compute + t.tp_comm + t.pp_comm + t.overhead).to_bits(),
                    cm.t_microbatch(c, b, s).to_bits(),
                    "{c} ({b},{s})"
                );
            }
        }
    }

    #[test]
    fn sync_time_small_but_positive() {
        let cm = cm7b_16();
        let s = cm.sync_time(8, 6);
        assert!(s > 0.0 && s < 0.5, "{s}");
    }
}
