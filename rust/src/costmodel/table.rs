//! Memoized cost-model lookups for the planning hot path.
//!
//! Deployment planning evaluates the Theorem-1 lower bound and the inner
//! dispatch problem on up to millions of candidate plans, and every single
//! evaluation needs `per_seq_cost(cfg, s_j)`, `max_seq_len(cfg)` and
//! `max_chunk_tokens(cfg)` for the same handful of (configuration ×
//! bucket-boundary) pairs. Those are pure functions of the (model, cluster,
//! config, boundary) tuple, so the planner precomputes them once per
//! candidate set × boundaries and reads them from this table instead of
//! re-deriving the analytic model from first principles each time.
//!
//! Values are stored exactly as computed by [`CostModel`], so lookups are
//! bit-identical to the uncached calls (certified by the
//! `costtable_bit_identical_to_costmodel` integration test); by-value
//! lookups fall back to the model for untabulated inputs, which keeps the
//! semantics identical everywhere the table is threaded through.

use crate::config::ParallelConfig;
use crate::costmodel::{BucketLoad, CostModel};

/// Precomputed per-(config × boundary) analytic costs.
#[derive(Debug, Clone)]
pub struct CostTable {
    cost: CostModel,
    configs: Vec<ParallelConfig>,
    /// Bucket boundaries (ascending), as padded lengths.
    boundaries: Vec<u64>,
    /// Per config: longest supported sequence.
    max_seq_len: Vec<u64>,
    /// Per config: memory-model chunk capacity.
    max_chunk_tokens: Vec<u64>,
    /// Config-major `[i * boundaries.len() + j]`: linear dispatch cost.
    per_seq: Vec<f64>,
    /// Config-major: sequences per full chunk, `(cap_i / s_j).max(1)`.
    per_chunk: Vec<u64>,
    /// Config-major: `t_microbatch(cfg_i, per_chunk_ij, s_j)`.
    t_full: Vec<f64>,
}

impl CostTable {
    /// Precompute every (config × boundary) entry from `cost`.
    pub fn build(
        cost: &CostModel,
        configs: &[ParallelConfig],
        boundaries: &[u32],
    ) -> Self {
        let bounds: Vec<u64> = boundaries.iter().map(|&b| b as u64).collect();
        let nb = bounds.len();
        let nc = configs.len();
        let mut max_seq_len = Vec::with_capacity(nc);
        let mut max_chunk_tokens = Vec::with_capacity(nc);
        let mut per_seq = Vec::with_capacity(nc * nb);
        let mut per_chunk = Vec::with_capacity(nc * nb);
        let mut t_full = Vec::with_capacity(nc * nb);
        for &cfg in configs {
            let cap = cost.max_chunk_tokens(cfg);
            max_chunk_tokens.push(cap);
            max_seq_len.push(cost.max_seq_len(cfg));
            for &s in &bounds {
                per_seq.push(cost.per_seq_cost(cfg, s));
                let b = (cap / s.max(1)).max(1);
                per_chunk.push(b);
                t_full.push(cost.t_microbatch(cfg, b, s));
            }
        }
        Self {
            cost: cost.clone(),
            configs: configs.to_vec(),
            boundaries: bounds,
            max_seq_len,
            max_chunk_tokens,
            per_seq,
            per_chunk,
            t_full,
        }
    }

    /// The tabulated configurations, in index order.
    pub fn configs(&self) -> &[ParallelConfig] {
        &self.configs
    }

    pub fn n_configs(&self) -> usize {
        self.configs.len()
    }

    /// Whether this table was built for exactly these boundaries.
    pub fn covers(&self, boundaries: &[u32]) -> bool {
        self.boundaries.len() == boundaries.len()
            && self
                .boundaries
                .iter()
                .zip(boundaries)
                .all(|(&a, &b)| a == b as u64)
    }

    #[inline]
    pub fn max_seq_len_at(&self, i: usize) -> u64 {
        self.max_seq_len[i]
    }

    #[inline]
    pub fn max_chunk_tokens_at(&self, i: usize) -> u64 {
        self.max_chunk_tokens[i]
    }

    #[inline]
    pub fn per_seq_cost_at(&self, i: usize, j: usize) -> f64 {
        self.per_seq[i * self.boundaries.len() + j]
    }

    fn config_index(&self, cfg: ParallelConfig) -> Option<usize> {
        self.configs.iter().position(|&c| c == cfg)
    }

    fn boundary_index(&self, s: u64) -> Option<usize> {
        self.boundaries.binary_search(&s).ok()
    }

    /// Memoized [`CostModel::max_seq_len`] (falls back for untabulated configs).
    pub fn max_seq_len(&self, cfg: ParallelConfig) -> u64 {
        match self.config_index(cfg) {
            Some(i) => self.max_seq_len[i],
            None => self.cost.max_seq_len(cfg),
        }
    }

    /// Memoized [`CostModel::max_chunk_tokens`] (falls back when untabulated).
    pub fn max_chunk_tokens(&self, cfg: ParallelConfig) -> u64 {
        match self.config_index(cfg) {
            Some(i) => self.max_chunk_tokens[i],
            None => self.cost.max_chunk_tokens(cfg),
        }
    }

    /// Memoized [`CostModel::per_seq_cost`] (falls back when untabulated).
    pub fn per_seq_cost(&self, cfg: ParallelConfig, s: u64) -> f64 {
        match (self.config_index(cfg), self.boundary_index(s)) {
            (Some(i), Some(j)) => self.per_seq_cost_at(i, j),
            _ => self.cost.per_seq_cost(cfg, s),
        }
    }

    /// Memoized [`CostModel::replica_time`]: bit-identical mirror of
    /// Eq. 10/12 with the full-chunk time read from the table; remainder
    /// chunks (variable batch) use the exact `t_microbatch`. Untabulated
    /// configs or padded lengths delegate wholesale to the model.
    pub fn replica_time(&self, cfg: ParallelConfig, loads: &[BucketLoad]) -> f64 {
        match self.config_index(cfg) {
            Some(i) => self.replica_time_at(i, loads),
            None => self.cost.replica_time(cfg, loads),
        }
    }

    /// Index-based [`Self::replica_time`] for the planner's inner loop.
    pub fn replica_time_at(&self, i: usize, loads: &[BucketLoad]) -> f64 {
        let cfg = self.configs[i];
        let nb = self.boundaries.len();
        let mut compute = 0.0;
        let mut max_chunk_t: f64 = 0.0;
        let mut any = false;
        for &BucketLoad { count: d, padded_len: s } in loads {
            if d == 0 {
                continue;
            }
            let Some(j) = self.boundary_index(s) else {
                return self.cost.replica_time(cfg, loads);
            };
            any = true;
            let b = self.per_chunk[i * nb + j];
            let t_chunk = self.t_full[i * nb + j];
            let full = d / b;
            compute += full as f64 * t_chunk;
            if full > 0 {
                max_chunk_t = max_chunk_t.max(t_chunk);
            }
            let rem = d % b;
            if rem > 0 {
                let t_rem = self.cost.t_microbatch(cfg, rem, s);
                compute += t_rem;
                max_chunk_t = max_chunk_t.max(t_rem);
            }
        }
        if !any {
            return 0.0;
        }
        let bubble = (cfg.pp as f64 - 1.0) * max_chunk_t;
        compute + bubble + super::STEP_OVERHEAD
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelDesc;

    fn world() -> (CostModel, Vec<ParallelConfig>, Vec<u32>) {
        let cost = CostModel::calibrated(
            &ModelDesc::llama2_7b(),
            &ClusterSpec::a100_40g(16),
        );
        let configs = vec![
            ParallelConfig::new(1, 1),
            ParallelConfig::new(2, 1),
            ParallelConfig::new(4, 2),
            ParallelConfig::new(8, 1),
        ];
        (cost, configs, vec![512, 2048, 8192])
    }

    #[test]
    fn lookups_match_model() {
        let (cost, configs, bounds) = world();
        let table = CostTable::build(&cost, &configs, &bounds);
        for (i, &cfg) in configs.iter().enumerate() {
            assert_eq!(table.max_seq_len_at(i), cost.max_seq_len(cfg));
            assert_eq!(table.max_chunk_tokens_at(i), cost.max_chunk_tokens(cfg));
            for (j, &s) in bounds.iter().enumerate() {
                let got = table.per_seq_cost_at(i, j);
                let want = cost.per_seq_cost(cfg, s as u64);
                assert_eq!(got.to_bits(), want.to_bits(), "{cfg} s={s}");
            }
        }
    }

    #[test]
    fn replica_time_matches_model() {
        let (cost, configs, bounds) = world();
        let table = CostTable::build(&cost, &configs, &bounds);
        let loads = vec![
            vec![BucketLoad { count: 13, padded_len: 512 }],
            vec![
                BucketLoad { count: 200, padded_len: 512 },
                BucketLoad { count: 7, padded_len: 2048 },
            ],
            vec![
                BucketLoad { count: 1, padded_len: 8192 },
                BucketLoad { count: 0, padded_len: 512 },
            ],
        ];
        for &cfg in &configs {
            for l in &loads {
                let got = table.replica_time(cfg, l);
                let want = cost.replica_time(cfg, l);
                assert_eq!(got.to_bits(), want.to_bits(), "{cfg} {l:?}");
            }
        }
    }

    #[test]
    fn fallback_for_untabulated_inputs() {
        let (cost, configs, bounds) = world();
        let table = CostTable::build(&cost, &configs, &bounds);
        let other = ParallelConfig::new(16, 1);
        assert_eq!(table.max_seq_len(other), cost.max_seq_len(other));
        let odd = 300u64; // not a tabulated boundary
        let cfg = configs[0];
        assert_eq!(
            table.per_seq_cost(cfg, odd).to_bits(),
            cost.per_seq_cost(cfg, odd).to_bits()
        );
        let off_loads = [BucketLoad { count: 3, padded_len: odd }];
        assert_eq!(
            table.replica_time(cfg, &off_loads).to_bits(),
            cost.replica_time(cfg, &off_loads).to_bits()
        );
    }

    #[test]
    fn covers_detects_boundary_changes() {
        let (cost, configs, bounds) = world();
        let table = CostTable::build(&cost, &configs, &bounds);
        assert!(table.covers(&bounds));
        assert!(!table.covers(&[512, 2048]));
        assert!(!table.covers(&[512, 2048, 4096]));
    }
}
