//! Memoized cost-model lookups for the planning hot path.
//!
//! Deployment planning evaluates the Theorem-1 lower bound and the inner
//! dispatch problem on up to millions of candidate plans, and every single
//! evaluation needs `per_seq_cost(cfg, s_j)`, `max_seq_len(cfg)` and
//! `max_chunk_tokens(cfg)` for the same handful of (configuration ×
//! bucket-boundary) pairs. Those are pure functions of the (model, cluster,
//! config, boundary) tuple, so the planner precomputes them once per
//! candidate set × boundaries and reads them from this table instead of
//! re-deriving the analytic model from first principles each time.
//!
//! Values are stored exactly as computed by [`CostModel`], so lookups are
//! bit-identical to the uncached calls (certified by the
//! `costtable_bit_identical_to_costmodel` integration test); by-value
//! lookups fall back to the model for untabulated inputs, which keeps the
//! semantics identical everywhere the table is threaded through.

use std::sync::{Arc, Mutex};

use crate::config::ParallelConfig;
use crate::costmodel::{BucketLoad, CostModel};

/// FNV-1a step (keeps [`structural_hash`] allocation- and RandomState-free,
/// so cache behaviour is reproducible across runs). Shared with the
/// session's task fingerprint so the hashing primitive lives in one place.
#[inline]
pub(crate) fn fnv1a(h: u64, v: u64) -> u64 {
    (h ^ v).wrapping_mul(0x100000001b3)
}

/// Cheap structural hash of a table's identity: the ordered candidate-config
/// set and the bucket boundaries. Used by [`CostTableLru`] to reject
/// non-matching entries without a full vector comparison.
pub fn structural_hash(configs: &[ParallelConfig], boundaries: &[u32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    h = fnv1a(h, configs.len() as u64);
    for c in configs {
        h = fnv1a(h, c.tp as u64);
        h = fnv1a(h, c.pp as u64);
    }
    h = fnv1a(h, 0x10b7a_5eed);
    h = fnv1a(h, boundaries.len() as u64);
    for &b in boundaries {
        h = fnv1a(h, b as u64);
    }
    h
}

/// Fingerprint of the *analytic* (model, cluster) world — the identity a
/// calibration profile is measured against. Deliberately excludes any
/// attached profile: a profile saved under this fingerprint stays loadable
/// by the same world regardless of how many recalibrations happened since.
pub fn world_fingerprint(
    m: &crate::config::ModelDesc,
    cl: &crate::cluster::ClusterSpec,
) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in m.name.as_bytes() {
        h = fnv1a(h, *b as u64);
    }
    for v in [
        m.n_layers as u64,
        m.d_model,
        m.n_heads as u64,
        m.d_ff,
        m.vocab,
        m.params,
        m.lora_rank as u64,
        m.weight_bytes,
    ] {
        h = fnv1a(h, v);
    }
    h = fnv1a(h, cl.n_gpus as u64);
    // The full device identity (every float the cost model reads plus the
    // generation name), so in a mixed-generation fleet each device type keys
    // its own cost tables in the shared LRU. The same fingerprint also keys
    // calibration profiles on its own (`DeviceProfile::fingerprint`).
    h = fnv1a(h, cl.device.fingerprint());
    h
}

/// Fingerprint of the full cost identity a table is built from: the
/// analytic [`world_fingerprint`] plus, when a calibration profile is
/// attached, the profile's generation and fitted coefficients. Folded into
/// [`CostTableKey`] so one shared LRU can serve several worlds without ever
/// returning another model's table — and so *recalibration changes the
/// key*: tables built from analytic constants (or from a stale profile
/// generation) are never served to a planner running on measured times.
pub fn cost_fingerprint(cost: &CostModel) -> u64 {
    let mut h = world_fingerprint(&cost.model, &cost.cluster);
    if let Some(profile) = cost.profile() {
        h = profile.fold_fingerprint(h);
    }
    h
}

/// Cache key identifying a [`CostTable`]'s inputs: the (model, cluster)
/// fingerprint, the ordered candidate-config set and the bucket boundaries.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CostTableKey {
    cost: u64,
    configs: Vec<ParallelConfig>,
    boundaries: Vec<u32>,
    hash: u64,
}

impl CostTableKey {
    pub fn new(cost: &CostModel, configs: &[ParallelConfig], boundaries: &[u32]) -> Self {
        let cost_fp = cost_fingerprint(cost);
        Self {
            cost: cost_fp,
            configs: configs.to_vec(),
            boundaries: boundaries.to_vec(),
            hash: fnv1a(structural_hash(configs, boundaries), cost_fp),
        }
    }

    pub fn hash(&self) -> u64 {
        self.hash
    }

    /// Allocation-free equality against raw inputs (hash checked first).
    pub fn matches(
        &self,
        cost_fp: u64,
        configs: &[ParallelConfig],
        boundaries: &[u32],
    ) -> bool {
        self.cost == cost_fp
            && self.configs.as_slice() == configs
            && self.boundaries.as_slice() == boundaries
    }
}

/// Bounded LRU of built [`CostTable`]s, keyed by [`CostTableKey`].
///
/// Planning and scheduling revisit the same (candidate set × boundaries)
/// contexts often — skewed workloads land the dynamic-bucketing DP on the
/// same boundary vectors, and churn traces cycle through recurring task
/// sets — so a handful of slots absorbs most rebuilds. Entries are shared
/// via `Arc`, so a hit is a pointer clone, never a table copy.
#[derive(Debug)]
pub struct CostTableLru {
    cap: usize,
    /// Most-recently-used first.
    entries: Vec<(CostTableKey, Arc<CostTable>)>,
    pub hits: u64,
    pub misses: u64,
}

impl CostTableLru {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "an LRU needs at least one slot");
        Self { cap, entries: Vec::new(), hits: 0, misses: 0 }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Probe for `(cost, configs, boundaries)`, counting a hit (and moving
    /// the entry to the front) or a miss.
    pub fn get(
        &mut self,
        cost_fp: u64,
        configs: &[ParallelConfig],
        boundaries: &[u32],
    ) -> Option<Arc<CostTable>> {
        let hash = fnv1a(structural_hash(configs, boundaries), cost_fp);
        if let Some(pos) = self
            .entries
            .iter()
            .position(|(k, _)| k.hash() == hash && k.matches(cost_fp, configs, boundaries))
        {
            self.hits += 1;
            let entry = self.entries.remove(pos);
            let table = entry.1.clone();
            self.entries.insert(0, entry);
            return Some(table);
        }
        self.misses += 1;
        None
    }

    /// Insert a built table, returning the cached one. If another caller
    /// raced the build and inserted the same key first, *their* table wins
    /// (it is bit-identical anyway) and the duplicate is dropped.
    pub fn insert(&mut self, key: CostTableKey, table: Arc<CostTable>) -> Arc<CostTable> {
        if let Some(pos) = self.entries.iter().position(|(k, _)| *k == key) {
            let entry = self.entries.remove(pos);
            let existing = entry.1.clone();
            self.entries.insert(0, entry);
            return existing;
        }
        self.entries.insert(0, (key, table.clone()));
        self.entries.truncate(self.cap);
        table
    }

    /// Fetch the table for `(cost, configs, boundaries)`, building (and
    /// caching) it on a miss. Hit or miss, the returned table is
    /// bit-identical to a fresh [`CostTable::build`] — entries are
    /// immutable once built.
    pub fn get_or_build(
        &mut self,
        cost: &CostModel,
        configs: &[ParallelConfig],
        boundaries: &[u32],
    ) -> Arc<CostTable> {
        let cost_fp = cost_fingerprint(cost);
        if let Some(table) = self.get(cost_fp, configs, boundaries) {
            return table;
        }
        let table = Arc::new(CostTable::build(cost, configs, boundaries));
        self.insert(CostTableKey::new(cost, configs, boundaries), table)
    }
}

/// Cloneable shared handle to a [`CostTableLru`].
///
/// The planning session and the scheduler draw their tables from the same
/// cache through this handle (the ROADMAP's "CostTable reuse across steps"):
/// clone it freely, all clones see one LRU.
#[derive(Debug, Clone)]
pub struct CostTables {
    inner: Arc<Mutex<CostTableLru>>,
}

impl CostTables {
    /// Default slot count: planning + per-step boundary vectors of a few
    /// concurrent contexts fit comfortably in 8 tables.
    pub const DEFAULT_CAPACITY: usize = 8;

    pub fn with_capacity(cap: usize) -> Self {
        Self { inner: Arc::new(Mutex::new(CostTableLru::new(cap))) }
    }

    /// See [`CostTableLru::get_or_build`]. The build itself runs *outside*
    /// the lock: a concurrent user that only needs an already-cached table
    /// (e.g. a scheduler step) never waits for a replan's table build. Two
    /// racing builders of the same key both build, but the first insert
    /// wins and the tables are bit-identical either way.
    pub fn get_or_build(
        &self,
        cost: &CostModel,
        configs: &[ParallelConfig],
        boundaries: &[u32],
    ) -> Arc<CostTable> {
        let cost_fp = cost_fingerprint(cost);
        {
            let mut guard = self.inner.lock().expect("cost-table cache poisoned");
            if let Some(table) = guard.get(cost_fp, configs, boundaries) {
                return table;
            }
        }
        let table = Arc::new(CostTable::build(cost, configs, boundaries));
        self.inner
            .lock()
            .expect("cost-table cache poisoned")
            .insert(CostTableKey::new(cost, configs, boundaries), table)
    }

    /// `(hits, misses)` so far.
    pub fn stats(&self) -> (u64, u64) {
        let g = self.inner.lock().expect("cost-table cache poisoned");
        (g.hits, g.misses)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("cost-table cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for CostTables {
    fn default() -> Self {
        Self::with_capacity(Self::DEFAULT_CAPACITY)
    }
}

/// Precomputed per-(config × boundary) analytic costs.
#[derive(Debug, Clone)]
pub struct CostTable {
    cost: CostModel,
    configs: Vec<ParallelConfig>,
    /// Bucket boundaries (ascending), as padded lengths.
    boundaries: Vec<u64>,
    /// Per config: longest supported sequence.
    max_seq_len: Vec<u64>,
    /// Per config: memory-model chunk capacity.
    max_chunk_tokens: Vec<u64>,
    /// Config-major `[i * boundaries.len() + j]`: linear dispatch cost.
    per_seq: Vec<f64>,
    /// Config-major: sequences per full chunk, `(cap_i / s_j).max(1)`.
    per_chunk: Vec<u64>,
    /// Config-major: `t_microbatch(cfg_i, per_chunk_ij, s_j)`.
    t_full: Vec<f64>,
}

impl CostTable {
    /// Precompute every (config × boundary) entry from `cost`.
    pub fn build(
        cost: &CostModel,
        configs: &[ParallelConfig],
        boundaries: &[u32],
    ) -> Self {
        let bounds: Vec<u64> = boundaries.iter().map(|&b| b as u64).collect();
        let nb = bounds.len();
        let nc = configs.len();
        let mut max_seq_len = Vec::with_capacity(nc);
        let mut max_chunk_tokens = Vec::with_capacity(nc);
        let mut per_seq = Vec::with_capacity(nc * nb);
        let mut per_chunk = Vec::with_capacity(nc * nb);
        let mut t_full = Vec::with_capacity(nc * nb);
        for &cfg in configs {
            let cap = cost.max_chunk_tokens(cfg);
            max_chunk_tokens.push(cap);
            max_seq_len.push(cost.max_seq_len(cfg));
            for &s in &bounds {
                per_seq.push(cost.per_seq_cost(cfg, s));
                let b = (cap / s.max(1)).max(1);
                per_chunk.push(b);
                t_full.push(cost.t_microbatch(cfg, b, s));
            }
        }
        Self {
            cost: cost.clone(),
            configs: configs.to_vec(),
            boundaries: bounds,
            max_seq_len,
            max_chunk_tokens,
            per_seq,
            per_chunk,
            t_full,
        }
    }

    /// The tabulated configurations, in index order.
    pub fn configs(&self) -> &[ParallelConfig] {
        &self.configs
    }

    pub fn n_configs(&self) -> usize {
        self.configs.len()
    }

    /// Whether this table was built for exactly these boundaries.
    pub fn covers(&self, boundaries: &[u32]) -> bool {
        self.boundaries.len() == boundaries.len()
            && self
                .boundaries
                .iter()
                .zip(boundaries)
                .all(|(&a, &b)| a == b as u64)
    }

    #[inline]
    pub fn max_seq_len_at(&self, i: usize) -> u64 {
        self.max_seq_len[i]
    }

    #[inline]
    pub fn max_chunk_tokens_at(&self, i: usize) -> u64 {
        self.max_chunk_tokens[i]
    }

    #[inline]
    pub fn per_seq_cost_at(&self, i: usize, j: usize) -> f64 {
        self.per_seq[i * self.boundaries.len() + j]
    }

    fn config_index(&self, cfg: ParallelConfig) -> Option<usize> {
        self.configs.iter().position(|&c| c == cfg)
    }

    fn boundary_index(&self, s: u64) -> Option<usize> {
        self.boundaries.binary_search(&s).ok()
    }

    /// Memoized [`CostModel::max_seq_len`] (falls back for untabulated configs).
    pub fn max_seq_len(&self, cfg: ParallelConfig) -> u64 {
        match self.config_index(cfg) {
            Some(i) => self.max_seq_len[i],
            None => self.cost.max_seq_len(cfg),
        }
    }

    /// Memoized [`CostModel::max_chunk_tokens`] (falls back when untabulated).
    pub fn max_chunk_tokens(&self, cfg: ParallelConfig) -> u64 {
        match self.config_index(cfg) {
            Some(i) => self.max_chunk_tokens[i],
            None => self.cost.max_chunk_tokens(cfg),
        }
    }

    /// Memoized [`CostModel::per_seq_cost`] (falls back when untabulated).
    pub fn per_seq_cost(&self, cfg: ParallelConfig, s: u64) -> f64 {
        match (self.config_index(cfg), self.boundary_index(s)) {
            (Some(i), Some(j)) => self.per_seq_cost_at(i, j),
            _ => self.cost.per_seq_cost(cfg, s),
        }
    }

    /// Memoized [`CostModel::replica_time`]: bit-identical mirror of
    /// Eq. 10/12 with the full-chunk time read from the table; remainder
    /// chunks (variable batch) use the exact `t_microbatch`. Untabulated
    /// configs or padded lengths delegate wholesale to the model.
    pub fn replica_time(&self, cfg: ParallelConfig, loads: &[BucketLoad]) -> f64 {
        match self.config_index(cfg) {
            Some(i) => self.replica_time_at(i, loads),
            None => self.cost.replica_time(cfg, loads),
        }
    }

    /// Index-based [`Self::replica_time`] for the planner's inner loop.
    pub fn replica_time_at(&self, i: usize, loads: &[BucketLoad]) -> f64 {
        let cfg = self.configs[i];
        let nb = self.boundaries.len();
        let mut compute = 0.0;
        let mut max_chunk_t: f64 = 0.0;
        let mut any = false;
        for &BucketLoad { count: d, padded_len: s } in loads {
            if d == 0 {
                continue;
            }
            let Some(j) = self.boundary_index(s) else {
                return self.cost.replica_time(cfg, loads);
            };
            any = true;
            let b = self.per_chunk[i * nb + j];
            let t_chunk = self.t_full[i * nb + j];
            let full = d / b;
            compute += full as f64 * t_chunk;
            if full > 0 {
                max_chunk_t = max_chunk_t.max(t_chunk);
            }
            let rem = d % b;
            if rem > 0 {
                let t_rem = self.cost.t_microbatch(cfg, rem, s);
                compute += t_rem;
                max_chunk_t = max_chunk_t.max(t_rem);
            }
        }
        if !any {
            return 0.0;
        }
        let bubble = (cfg.pp as f64 - 1.0) * max_chunk_t;
        compute + bubble + super::STEP_OVERHEAD
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::config::ModelDesc;

    fn world() -> (CostModel, Vec<ParallelConfig>, Vec<u32>) {
        let cost = CostModel::calibrated(
            &ModelDesc::llama2_7b(),
            &ClusterSpec::a100_40g(16),
        );
        let configs = vec![
            ParallelConfig::new(1, 1),
            ParallelConfig::new(2, 1),
            ParallelConfig::new(4, 2),
            ParallelConfig::new(8, 1),
        ];
        (cost, configs, vec![512, 2048, 8192])
    }

    #[test]
    fn lookups_match_model() {
        let (cost, configs, bounds) = world();
        let table = CostTable::build(&cost, &configs, &bounds);
        for (i, &cfg) in configs.iter().enumerate() {
            assert_eq!(table.max_seq_len_at(i), cost.max_seq_len(cfg));
            assert_eq!(table.max_chunk_tokens_at(i), cost.max_chunk_tokens(cfg));
            for (j, &s) in bounds.iter().enumerate() {
                let got = table.per_seq_cost_at(i, j);
                let want = cost.per_seq_cost(cfg, s as u64);
                assert_eq!(got.to_bits(), want.to_bits(), "{cfg} s={s}");
            }
        }
    }

    #[test]
    fn replica_time_matches_model() {
        let (cost, configs, bounds) = world();
        let table = CostTable::build(&cost, &configs, &bounds);
        let loads = vec![
            vec![BucketLoad { count: 13, padded_len: 512 }],
            vec![
                BucketLoad { count: 200, padded_len: 512 },
                BucketLoad { count: 7, padded_len: 2048 },
            ],
            vec![
                BucketLoad { count: 1, padded_len: 8192 },
                BucketLoad { count: 0, padded_len: 512 },
            ],
        ];
        for &cfg in &configs {
            for l in &loads {
                let got = table.replica_time(cfg, l);
                let want = cost.replica_time(cfg, l);
                assert_eq!(got.to_bits(), want.to_bits(), "{cfg} {l:?}");
            }
        }
    }

    #[test]
    fn fallback_for_untabulated_inputs() {
        let (cost, configs, bounds) = world();
        let table = CostTable::build(&cost, &configs, &bounds);
        let other = ParallelConfig::new(16, 1);
        assert_eq!(table.max_seq_len(other), cost.max_seq_len(other));
        let odd = 300u64; // not a tabulated boundary
        let cfg = configs[0];
        assert_eq!(
            table.per_seq_cost(cfg, odd).to_bits(),
            cost.per_seq_cost(cfg, odd).to_bits()
        );
        let off_loads = [BucketLoad { count: 3, padded_len: odd }];
        assert_eq!(
            table.replica_time(cfg, &off_loads).to_bits(),
            cost.replica_time(cfg, &off_loads).to_bits()
        );
    }

    #[test]
    fn covers_detects_boundary_changes() {
        let (cost, configs, bounds) = world();
        let table = CostTable::build(&cost, &configs, &bounds);
        assert!(table.covers(&bounds));
        assert!(!table.covers(&[512, 2048]));
        assert!(!table.covers(&[512, 2048, 4096]));
    }

    #[test]
    fn structural_hash_distinguishes_contexts() {
        let (_, configs, bounds) = world();
        let h = structural_hash(&configs, &bounds);
        assert_eq!(h, structural_hash(&configs, &bounds), "deterministic");
        let mut other_bounds = bounds.clone();
        other_bounds[0] += 256;
        assert_ne!(h, structural_hash(&configs, &other_bounds));
        let mut other_cfgs = configs.clone();
        other_cfgs.swap(0, 1);
        assert_ne!(h, structural_hash(&other_cfgs, &bounds), "order matters");
        assert_ne!(h, structural_hash(&configs[..2], &bounds));
    }

    #[test]
    fn lru_hits_share_and_evict() {
        let (cost, configs, bounds) = world();
        let mut lru = CostTableLru::new(2);
        let a = lru.get_or_build(&cost, &configs, &bounds);
        assert_eq!((lru.hits, lru.misses), (0, 1));
        let a2 = lru.get_or_build(&cost, &configs, &bounds);
        assert_eq!((lru.hits, lru.misses), (1, 1));
        assert!(Arc::ptr_eq(&a, &a2), "hit must share the built table");

        let b1 = vec![256u32, 1024];
        let b2 = vec![256u32, 4096];
        lru.get_or_build(&cost, &configs, &b1);
        // touch the original so `b1` is the LRU victim
        lru.get_or_build(&cost, &configs, &bounds);
        lru.get_or_build(&cost, &configs, &b2); // evicts b1
        assert_eq!(lru.len(), 2);
        let misses_before = lru.misses;
        lru.get_or_build(&cost, &configs, &b1); // must rebuild
        assert_eq!(lru.misses, misses_before + 1);
    }

    #[test]
    fn cache_key_separates_worlds() {
        // identical configs + boundaries but a different (model, cluster):
        // the shared cache must never serve the other world's table
        let (cost7, configs, bounds) = world();
        let cost70 = CostModel::calibrated(
            &ModelDesc::llama2_70b(),
            &ClusterSpec::a100_40g(16),
        );
        assert_ne!(cost_fingerprint(&cost7), cost_fingerprint(&cost70));
        let tables = CostTables::with_capacity(4);
        let a = tables.get_or_build(&cost7, &configs, &bounds);
        let b = tables.get_or_build(&cost70, &configs, &bounds);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(tables.stats(), (0, 2), "different worlds are distinct keys");
        for (i, &cfg) in configs.iter().enumerate() {
            assert_eq!(b.max_seq_len_at(i), cost70.max_seq_len(cfg), "{cfg}");
        }
        // and each world still hits its own entry
        let a2 = tables.get_or_build(&cost7, &configs, &bounds);
        assert!(Arc::ptr_eq(&a, &a2));
    }

    #[test]
    fn shared_handle_sees_one_cache() {
        let (cost, configs, bounds) = world();
        let tables = CostTables::with_capacity(4);
        let clone = tables.clone();
        let a = tables.get_or_build(&cost, &configs, &bounds);
        let b = clone.get_or_build(&cost, &configs, &bounds);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(tables.stats(), (1, 1));
        // cached lookups stay bit-identical to the uncached model
        for (i, &cfg) in configs.iter().enumerate() {
            for (j, &s) in bounds.iter().enumerate() {
                assert_eq!(
                    b.per_seq_cost_at(i, j).to_bits(),
                    cost.per_seq_cost(cfg, s as u64).to_bits()
                );
            }
        }
    }
}
