//! In-situ cost-model calibration (paper Appendix D methodology).
//!
//! The paper builds `t(b, s)` by offline-profiling real training steps and
//! fitting a function linear in `b` and quadratic in `s`:
//!
//! ```text
//! t(b, s) = β₀ + β₁·b·s + β₂·b·s²
//! ```
//!
//! (`β₁` captures the per-token dense work, `β₂` the attention term, `β₀`
//! fixed launch overhead.) This module closes that loop for the live
//! system instead of requiring a separate offline profiling pass:
//!
//! * **Observations come from the executors.** Both
//!   [`crate::exec::PjrtExecutor`] (real per-microbatch wall-clocks) and
//!   [`crate::exec::SimExecutor`] (the deterministic test double: exact
//!   analytic chunk times) tag every executed microbatch with a
//!   `(ParallelConfig, Observation)` pair in
//!   [`crate::exec::StepExecution::observations`].
//! * **A [`CalibrationStore`] accumulates them across steps**, one
//!   observation set per parallel configuration, and refits
//!   [`FittedCost`] incrementally via [`fit`] (least squares with column
//!   equilibration). Every refit bumps the store's *generation*.
//! * **Profiles persist as JSON** keyed by the analytic
//!   [`world_fingerprint`](crate::costmodel::world_fingerprint) of the
//!   `(model, cluster)` world they were measured on
//!   ([`CalibrationStore::save`] / [`CalibrationStore::load`]); a profile
//!   from a different world never attaches
//!   ([`CostModel::from_profile`](crate::costmodel::CostModel::from_profile)
//!   rejects it), and a corrupt file falls back to the analytic constants
//!   with a warning ([`load_profile_or_analytic`]).
//! * **Recalibration invalidates stale cost tables.** The attached
//!   [`CalibrationProfile`]'s generation and coefficients are folded into
//!   [`cost_fingerprint`](crate::costmodel::cost_fingerprint), which keys
//!   the shared [`CostTableLru`](crate::costmodel::CostTableLru) and the
//!   planning-session memo — a warm replan can never mix analytic and
//!   measured tables.
//!
//! Surfaces: `lobra calibrate` (sim-backed profiling run → profile JSON),
//! `lobra train --profile <path>` / `lobra plan --profile <path>` (plan
//! from measured times), `lobra train --save-profile <path>` (persist the
//! real run's in-situ observations), and `benches/calibration.rs` (fit
//! quality + analytic-vs-fitted divergence → `BENCH_calibration.json`).

use crate::cluster::ClusterSpec;
use crate::config::{ModelDesc, ParallelConfig};
use crate::costmodel::CostModel;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

use super::fnv1a;
use super::table::world_fingerprint;

/// Schema marker of the persisted profile JSON.
const PROFILE_KIND: &str = "lobra-calibration-profile";
/// Bump when the persisted schema changes incompatibly.
const PROFILE_VERSION: u64 = 1;
/// Per-configuration observation cap: beyond this the store keeps a FIFO
/// ring of the most recent measurements. Bounds the resident memory and
/// the persisted JSON of arbitrarily long training runs (a 100k-step run
/// would otherwise accumulate millions of observations) while biasing the
/// fit toward *recent* steps — the ones past any warmup.
const MAX_OBS_PER_CONFIG: usize = 4096;

/// One profiled observation: a microbatch of `b` sequences × `s` tokens
/// took `seconds`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub b: u64,
    pub s: u64,
    pub seconds: f64,
}

/// Fitted per-microbatch time model `t(b,s) = β₀ + β₁·b·s + β₂·b·s²`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FittedCost {
    pub beta0: f64,
    pub beta1: f64,
    pub beta2: f64,
}

impl FittedCost {
    /// Predicted microbatch seconds.
    pub fn predict(&self, b: u64, s: u64) -> f64 {
        let bs = (b * s) as f64;
        self.beta0 + self.beta1 * bs + self.beta2 * bs * s as f64
    }

    /// Relative RMS error over a set of observations; `None` when the set
    /// is empty (an empty set carries no evidence of fit quality — the old
    /// `0.0` return read as a *perfect* fit).
    pub fn rms_rel_error(&self, obs: &[Observation]) -> Option<f64> {
        if obs.is_empty() {
            return None;
        }
        let se: f64 = obs
            .iter()
            .map(|o| {
                let p = self.predict(o.b, o.s);
                let r = (p - o.seconds) / o.seconds.max(1e-12);
                r * r
            })
            .sum();
        Some((se / obs.len() as f64).sqrt())
    }
}

/// Least-squares fit of the 3-parameter model via the normal equations
/// (the design matrix is tiny: 3 columns).
///
/// Columns are equilibrated by their largest magnitude before forming
/// `AᵀA`: with sequence lengths up to 16K the raw `b·s²` column reaches
/// ~1e8 and squaring it would push the normal equations to ~1e16 condition,
/// destroying the constant term. Collinear observation sets (e.g. every
/// microbatch at one sequence length) are reported as `None` — the caller
/// keeps its analytic constants for that configuration.
pub fn fit(obs: &[Observation]) -> Option<FittedCost> {
    if obs.len() < 3 {
        return None;
    }
    // columns: [1, b·s, b·s²]
    let rows: Vec<[f64; 3]> = obs
        .iter()
        .map(|o| {
            let bs = (o.b * o.s) as f64;
            [1.0, bs, bs * o.s as f64]
        })
        .collect();
    let mut scale = [0.0f64; 3];
    for row in &rows {
        for (sc, v) in scale.iter_mut().zip(row) {
            *sc = sc.max(v.abs());
        }
    }
    for sc in &mut scale {
        if *sc <= 0.0 {
            *sc = 1.0;
        }
    }
    // AᵀA (3x3) and Aᵀy over the equilibrated columns
    let mut ata = [[0.0f64; 3]; 3];
    let mut aty = [0.0f64; 3];
    for (row, o) in rows.iter().zip(obs) {
        let sr = [row[0] / scale[0], row[1] / scale[1], row[2] / scale[2]];
        for i in 0..3 {
            for j in 0..3 {
                ata[i][j] += sr[i] * sr[j];
            }
            aty[i] += sr[i] * o.seconds;
        }
    }
    // Singularity tolerance relative to the equilibrated matrix scale
    // (entries are O(n)): exact collinearity cancels to pivots of order
    // n·eps, far below this; genuinely diverse shapes sit far above.
    let tol = 1e-10 * obs.len() as f64;
    let beta = solve3(ata, aty, tol)?;
    let beta = [beta[0] / scale[0], beta[1] / scale[1], beta[2] / scale[2]];
    Some(FittedCost { beta0: beta[0].max(0.0), beta1: beta[1], beta2: beta[2] })
}

/// Solve a 3×3 linear system by Gaussian elimination with partial
/// pivoting; `None` when a pivot falls below `tol` (singular system).
fn solve3(mut a: [[f64; 3]; 3], mut y: [f64; 3], tol: f64) -> Option<[f64; 3]> {
    for col in 0..3 {
        // pivot
        let mut piv = col;
        for r in col + 1..3 {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < tol {
            return None;
        }
        a.swap(col, piv);
        y.swap(col, piv);
        // eliminate
        for r in col + 1..3 {
            let f = a[r][col] / a[col][col];
            for c in col..3 {
                a[r][c] -= f * a[col][c];
            }
            y[r] -= f * y[col];
        }
    }
    // back-substitute
    let mut x = [0.0f64; 3];
    for col in (0..3).rev() {
        let mut s = y[col];
        for c in col + 1..3 {
            s -= a[col][c] * x[c];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

/// One configuration's accumulated measurements and (re)fitted model.
#[derive(Debug, Clone)]
pub struct ConfigCalibration {
    pub config: ParallelConfig,
    /// Bounded FIFO ring of the most recent [`MAX_OBS_PER_CONFIG`]
    /// measurements (ring order, not arrival order, once full).
    pub observations: Vec<Observation>,
    /// `None` until ≥3 shape-diverse observations arrive (underdetermined
    /// or collinear sets keep the analytic constants).
    pub fitted: Option<FittedCost>,
    /// Total measurements ever recorded (≥ `observations.len()`); drives
    /// the ring's replacement slot and survives persistence.
    pub recorded: u64,
}

impl ConfigCalibration {
    /// Fit quality against this configuration's own observations.
    pub fn rms_rel_error(&self) -> Option<f64> {
        self.fitted.and_then(|f| f.rms_rel_error(&self.observations))
    }
}

/// Accumulates executor [`Observation`]s across steps, refits
/// [`FittedCost`] per configuration, and persists/loads the result as a
/// JSON profile keyed by the `(model, cluster)` [`world_fingerprint`].
///
/// The *generation* counter increments on every refit that absorbed new
/// observations; it is carried into the [`CalibrationProfile`] and from
/// there into [`cost_fingerprint`](crate::costmodel::cost_fingerprint), so
/// recalibration re-keys every cost table built from the profile.
#[derive(Debug, Clone)]
pub struct CalibrationStore {
    fingerprint: u64,
    model: String,
    cluster: String,
    generation: u64,
    dirty: bool,
    entries: Vec<ConfigCalibration>,
}

impl CalibrationStore {
    /// A store keyed to `cost`'s analytic `(model, cluster)` world. (An
    /// already-profiled cost model keys to the same world: fingerprints
    /// name what was *measured on*, not the measurement itself.)
    pub fn new(cost: &CostModel) -> Self {
        Self::for_world(&cost.model, &cost.cluster)
    }

    /// A store keyed to an explicit `(model, cluster)` world.
    pub fn for_world(model: &ModelDesc, cluster: &ClusterSpec) -> Self {
        Self {
            fingerprint: world_fingerprint(model, cluster),
            model: model.name.clone(),
            cluster: cluster.name.clone(),
            generation: 0,
            dirty: false,
            entries: Vec::new(),
        }
    }

    /// Analytic world fingerprint this store's measurements belong to.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Human-readable model name of the measured world.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Human-readable cluster name of the measured world.
    pub fn cluster(&self) -> &str {
        &self.cluster
    }

    /// Profile generation: bumped by every refit that saw new data.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Per-configuration calibrations, in first-seen order.
    pub fn entries(&self) -> &[ConfigCalibration] {
        &self.entries
    }

    /// Total recorded observations across configurations.
    pub fn n_observations(&self) -> usize {
        self.entries.iter().map(|e| e.observations.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record one microbatch measurement. Non-positive or non-finite
    /// durations are dropped (a timer glitch must not poison the fit);
    /// past [`MAX_OBS_PER_CONFIG`] per configuration, the oldest
    /// measurement is replaced (FIFO ring), keeping long runs bounded.
    pub fn record(&mut self, config: ParallelConfig, b: u64, s: u64, seconds: f64) {
        if b == 0 || s == 0 || !seconds.is_finite() || seconds <= 0.0 {
            return;
        }
        let obs = Observation { b, s, seconds };
        match self.entries.iter().position(|e| e.config == config) {
            Some(i) => {
                let e = &mut self.entries[i];
                if e.observations.len() < MAX_OBS_PER_CONFIG {
                    e.observations.push(obs);
                } else {
                    let slot = (e.recorded % MAX_OBS_PER_CONFIG as u64) as usize;
                    e.observations[slot] = obs;
                }
                e.recorded += 1;
            }
            None => self.entries.push(ConfigCalibration {
                config,
                observations: vec![obs],
                fitted: None,
                recorded: 1,
            }),
        }
        self.dirty = true;
    }

    /// Record a step's worth of executor observations
    /// ([`crate::exec::StepExecution::observations`]).
    pub fn record_all(&mut self, obs: &[(ParallelConfig, Observation)]) {
        for &(config, o) in obs {
            self.record(config, o.b, o.s, o.seconds);
        }
    }

    /// Refit every configuration from its accumulated observations; bumps
    /// the generation when new observations arrived since the last fit.
    /// Returns the number of configurations with a usable fit.
    pub fn refit(&mut self) -> usize {
        if self.dirty {
            for e in &mut self.entries {
                e.fitted = fit(&e.observations);
            }
            self.generation += 1;
            self.dirty = false;
        }
        self.entries.iter().filter(|e| e.fitted.is_some()).count()
    }

    /// The current fit for `config`, if any (refit first to pick up new
    /// observations).
    pub fn fitted_for(&self, config: ParallelConfig) -> Option<FittedCost> {
        self.entries.iter().find(|e| e.config == config).and_then(|e| e.fitted)
    }

    /// Snapshot the fitted state as an attachable [`CalibrationProfile`]
    /// (refitting first if observations arrived since the last fit).
    pub fn profile(&mut self) -> CalibrationProfile {
        self.refit();
        CalibrationProfile {
            fingerprint: self.fingerprint,
            generation: self.generation,
            entries: self
                .entries
                .iter()
                .filter_map(|e| e.fitted.map(|f| (e.config, f)))
                .collect(),
        }
    }

    /// Serialize the full store (metadata, per-config fits *and* raw
    /// observations, so a later session can keep accumulating).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"kind\": \"{PROFILE_KIND}\",\n"));
        out.push_str(&format!("  \"version\": {PROFILE_VERSION},\n"));
        out.push_str(&format!("  \"model\": \"{}\",\n", self.model));
        out.push_str(&format!("  \"cluster\": \"{}\",\n", self.cluster));
        out.push_str(&format!("  \"fingerprint\": \"{:016x}\",\n", self.fingerprint));
        out.push_str(&format!("  \"generation\": {},\n", self.generation));
        out.push_str("  \"configs\": [");
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\n      \"tp\": {}, \"pp\": {}, \"recorded\": {},\n",
                e.config.tp, e.config.pp, e.recorded
            ));
            match e.fitted {
                Some(f) => out.push_str(&format!(
                    "      \"fit\": {{\"beta0\": {:?}, \"beta1\": {:?}, \"beta2\": {:?}}},\n",
                    f.beta0, f.beta1, f.beta2
                )),
                None => out.push_str("      \"fit\": null,\n"),
            }
            out.push_str("      \"observations\": [");
            for (k, o) in e.observations.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "\n        {{\"b\": {}, \"s\": {}, \"seconds\": {:?}}}",
                    o.b, o.s, o.seconds
                ));
            }
            if !e.observations.is_empty() {
                out.push_str("\n      ");
            }
            out.push_str("]\n    }");
        }
        if !self.entries.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }

    /// Parse a store previously written by [`Self::to_json`]. Strict:
    /// wrong kind/version, a garbled fingerprint, or missing fields are
    /// errors (callers that want the analytic fallback use
    /// [`load_profile_or_analytic`]).
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("invalid profile JSON: {e}"))?;
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("");
        if kind != PROFILE_KIND {
            return Err(anyhow!("not a calibration profile (kind {kind:?})"));
        }
        let version = j.get("version").and_then(Json::as_u64).unwrap_or(0);
        if version != PROFILE_VERSION {
            return Err(anyhow!("unsupported profile version {version}"));
        }
        let fp_hex = j
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("profile missing fingerprint"))?;
        let fingerprint = u64::from_str_radix(fp_hex, 16)
            .map_err(|_| anyhow!("bad profile fingerprint {fp_hex:?}"))?;
        let generation = j
            .get("generation")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("profile missing generation"))?;
        let model = j.get("model").and_then(Json::as_str).unwrap_or("?").to_string();
        let cluster = j.get("cluster").and_then(Json::as_str).unwrap_or("?").to_string();
        let configs = j
            .get("configs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("profile missing configs"))?;
        let mut entries = Vec::with_capacity(configs.len());
        for c in configs {
            let tp = c
                .get("tp")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("config entry missing tp"))?;
            let pp = c
                .get("pp")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("config entry missing pp"))?;
            let config = ParallelConfig::new(tp as u32, pp as u32);
            let fitted = match c.get("fit") {
                None | Some(Json::Null) => None,
                Some(f) => Some(FittedCost {
                    beta0: f
                        .get("beta0")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("fit for {config} missing beta0"))?,
                    beta1: f
                        .get("beta1")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("fit for {config} missing beta1"))?,
                    beta2: f
                        .get("beta2")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("fit for {config} missing beta2"))?,
                }),
            };
            let mut observations = Vec::new();
            if let Some(arr) = c.get("observations").and_then(Json::as_arr) {
                for o in arr {
                    observations.push(Observation {
                        b: o
                            .get("b")
                            .and_then(Json::as_u64)
                            .ok_or_else(|| anyhow!("observation missing b"))?,
                        s: o
                            .get("s")
                            .and_then(Json::as_u64)
                            .ok_or_else(|| anyhow!("observation missing s"))?,
                        seconds: o
                            .get("seconds")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| anyhow!("observation missing seconds"))?,
                    });
                }
            }
            let recorded = c
                .get("recorded")
                .and_then(Json::as_u64)
                .unwrap_or(observations.len() as u64);
            entries.push(ConfigCalibration { config, observations, fitted, recorded });
        }
        Ok(Self { fingerprint, model, cluster, generation, dirty: false, entries })
    }

    /// Write the store to `path` as JSON.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow!("could not write profile {path}: {e}"))
    }

    /// Load a store from `path`.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("could not read profile {path}: {e}"))?;
        Self::from_json(&text)
    }
}

/// Immutable fitted snapshot a [`CostModel`](crate::costmodel::CostModel)
/// plans against: per-configuration measured `t(b,s)` coefficients plus the
/// identity (world fingerprint, generation) that keys cost tables built
/// from it.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationProfile {
    fingerprint: u64,
    generation: u64,
    entries: Vec<(ParallelConfig, FittedCost)>,
}

impl CalibrationProfile {
    /// Analytic world fingerprint the profile was measured on.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn n_configs(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Configurations with measured coefficients.
    pub fn configs(&self) -> impl Iterator<Item = ParallelConfig> + '_ {
        self.entries.iter().map(|&(c, _)| c)
    }

    /// Measured coefficients for `config`; configurations never profiled
    /// fall back to the analytic model.
    pub fn fitted_for(&self, config: ParallelConfig) -> Option<&FittedCost> {
        self.entries.iter().find(|(c, _)| *c == config).map(|(_, f)| f)
    }

    /// Fold the profile identity (generation + coefficients) into a cost
    /// fingerprint so recalibration re-keys every dependent cost table.
    pub(crate) fn fold_fingerprint(&self, mut h: u64) -> u64 {
        h = fnv1a(h, 0x9caf_11b7);
        h = fnv1a(h, self.generation);
        h = fnv1a(h, self.entries.len() as u64);
        for (cfg, f) in &self.entries {
            h = fnv1a(h, cfg.tp as u64);
            h = fnv1a(h, cfg.pp as u64);
            h = fnv1a(h, f.beta0.to_bits());
            h = fnv1a(h, f.beta1.to_bits());
            h = fnv1a(h, f.beta2.to_bits());
        }
        h
    }
}

/// Build the cost model for `(model, cluster)` from the profile at `path`,
/// falling back to the analytic constants with a warning when the file is
/// missing, corrupt, measured on a different world, or holds no usable
/// fit. The training/planning CLI must keep working when a profile rots —
/// silently planning garbage would be worse than planning analytically.
pub fn load_profile_or_analytic(
    path: &str,
    model: &ModelDesc,
    cluster: &ClusterSpec,
) -> CostModel {
    let attached = CalibrationStore::load(path)
        .and_then(|mut store| CostModel::from_profile(model, cluster, store.profile()));
    match attached {
        Ok(cost) => cost,
        Err(e) => {
            eprintln!("warning: {e}; falling back to the analytic cost model");
            CostModel::calibrated(model, cluster)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(beta: FittedCost, shapes: &[(u64, u64)]) -> Vec<Observation> {
        shapes
            .iter()
            .map(|&(b, s)| Observation { b, s, seconds: beta.predict(b, s) })
            .collect()
    }

    #[test]
    fn fit_recovers_exact_model() {
        let truth = FittedCost { beta0: 0.002, beta1: 3e-6, beta2: 2e-9 };
        let obs = synth(truth, &[(16, 64), (8, 128), (4, 256), (2, 512), (1, 1024), (32, 64)]);
        let f = fit(&obs).unwrap();
        assert!((f.beta0 - truth.beta0).abs() < 1e-6, "{f:?}");
        assert!((f.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6);
        assert!((f.beta2 - truth.beta2).abs() / truth.beta2 < 1e-6);
        assert!(f.rms_rel_error(&obs).unwrap() < 1e-9);
    }

    #[test]
    fn fit_survives_long_sequence_conditioning() {
        // pre-equilibration, b·s² up to 16K² pushed AᵀA to ~1e16 condition
        // and the recovered β₀ was garbage
        let truth = FittedCost { beta0: 0.004, beta1: 2.5e-6, beta2: 1.5e-9 };
        let obs = synth(
            truth,
            &[(32, 512), (8, 2048), (2, 8192), (1, 16384), (16, 512), (3, 2048), (1, 8192)],
        );
        let f = fit(&obs).unwrap();
        assert!((f.beta0 - truth.beta0).abs() / truth.beta0 < 1e-3, "{f:?}");
        for &(b, s) in &[(4u64, 1024u64), (1, 12288), (64, 256)] {
            let want = truth.predict(b, s);
            let got = f.predict(b, s);
            assert!((got - want).abs() / want < 1e-6, "({b},{s}): {got} vs {want}");
        }
    }

    #[test]
    fn fit_tolerates_noise() {
        let truth = FittedCost { beta0: 0.01, beta1: 5e-6, beta2: 1e-9 };
        let mut rng = crate::util::Rng::new(3);
        let obs: Vec<Observation> = [(16u64, 64u64), (8, 128), (4, 256), (2, 512), (8, 64), (4, 128), (2, 256), (1, 512)]
            .iter()
            .map(|&(b, s)| Observation {
                b,
                s,
                seconds: truth.predict(b, s) * (1.0 + 0.05 * rng.normal()),
            })
            .collect();
        let f = fit(&obs).unwrap();
        assert!(f.rms_rel_error(&obs).unwrap() < 0.15);
        // prediction at an unseen shape within 20%
        let pred = f.predict(3, 384);
        let want = truth.predict(3, 384);
        assert!((pred - want).abs() / want < 0.2, "pred {pred} want {want}");
    }

    #[test]
    fn underdetermined_returns_none() {
        assert!(fit(&[Observation { b: 1, s: 64, seconds: 0.1 }]).is_none());
        // colinear observations (same b·s and b·s²) are singular
        let o = Observation { b: 2, s: 128, seconds: 0.5 };
        assert!(fit(&[o, o, o]).is_none());
    }

    #[test]
    fn empty_rms_is_none_not_perfect() {
        // regression: 0.0 for an empty set read as a perfect fit
        let f = FittedCost { beta0: 1.0, beta1: 1.0, beta2: 1.0 };
        assert_eq!(f.rms_rel_error(&[]), None);
    }

    #[test]
    fn store_records_fits_and_bumps_generation() {
        let truth = FittedCost { beta0: 0.003, beta1: 2e-6, beta2: 1e-9 };
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster);
        assert_eq!(store.generation(), 0);
        assert_eq!(store.refit(), 0, "refit without data must not bump");
        assert_eq!(store.generation(), 0);

        let cfg = ParallelConfig::new(2, 1);
        for &(b, s) in &[(16u64, 64u64), (8, 128), (4, 256), (2, 512), (32, 64)] {
            store.record(cfg, b, s, truth.predict(b, s));
        }
        assert_eq!(store.n_observations(), 5);
        assert_eq!(store.refit(), 1);
        assert_eq!(store.generation(), 1);
        let f = store.fitted_for(cfg).unwrap();
        assert!((f.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6);
        // refit with no new data: generation stable
        assert_eq!(store.refit(), 1);
        assert_eq!(store.generation(), 1);
        // one more observation → next refit bumps again
        store.record(cfg, 1, 1024, truth.predict(1, 1024));
        store.refit();
        assert_eq!(store.generation(), 2);
    }

    #[test]
    fn store_drops_garbage_measurements() {
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster);
        let cfg = ParallelConfig::new(1, 1);
        store.record(cfg, 0, 128, 0.5);
        store.record(cfg, 4, 128, -1.0);
        store.record(cfg, 4, 128, f64::NAN);
        store.record(cfg, 4, 0, 0.5);
        assert_eq!(store.n_observations(), 0);
        assert!(store.is_empty());
    }

    #[test]
    fn profile_lookup_and_fold() {
        let truth = FittedCost { beta0: 0.003, beta1: 2e-6, beta2: 1e-9 };
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster);
        let cfg = ParallelConfig::new(1, 1);
        for &(b, s) in &[(16u64, 64u64), (8, 128), (4, 256), (2, 512), (32, 64)] {
            store.record(cfg, b, s, truth.predict(b, s));
        }
        let p = store.profile();
        assert_eq!(p.n_configs(), 1);
        assert!(p.fitted_for(cfg).is_some());
        assert!(p.fitted_for(ParallelConfig::new(8, 1)).is_none());
        // folding is generation-sensitive
        let h1 = p.fold_fingerprint(0x1234);
        store.record(cfg, 1, 1024, truth.predict(1, 1024));
        let p2 = store.profile();
        assert_ne!(p.generation(), p2.generation());
        assert_ne!(h1, p2.fold_fingerprint(0x1234));
    }

    #[test]
    fn observation_ring_is_bounded() {
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster);
        let cfg = ParallelConfig::new(1, 1);
        let truth = FittedCost { beta0: 0.003, beta1: 2e-6, beta2: 1e-9 };
        let n = super::MAX_OBS_PER_CONFIG + 5;
        for i in 0..n {
            // cycle shapes so the final window still spans the model rank
            let (b, s) = [(16u64, 64u64), (8, 128), (4, 256), (2, 512), (32, 64)]
                [i % 5];
            store.record(cfg, b, s, truth.predict(b, s));
        }
        let e = &store.entries()[0];
        assert_eq!(e.observations.len(), super::MAX_OBS_PER_CONFIG);
        assert_eq!(e.recorded, n as u64);
        // the ring still fits (recent window is shape-diverse)
        store.refit();
        let f = store.fitted_for(cfg).unwrap();
        assert!((f.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6);
    }

    #[test]
    fn quadratic_term_matters_for_long_sequences() {
        let f = FittedCost { beta0: 0.0, beta1: 1e-6, beta2: 1e-9 };
        // same token budget, longer sequences cost more (attention term)
        assert!(f.predict(1, 4096) > f.predict(16, 256));
    }
}
