//! In-situ cost-model calibration (paper Appendix D methodology).
//!
//! The paper builds `t(b, s)` by offline-profiling real training steps and
//! fitting a function linear in `b` and quadratic in `s`:
//!
//! ```text
//! t(b, s) = β₀ + β₁·b·s + β₂·b·s²
//! ```
//!
//! (`β₁` captures the per-token dense work, `β₂` the attention term, `β₀`
//! fixed launch overhead.) This module closes that loop for the live
//! system instead of requiring a separate offline profiling pass:
//!
//! * **Observations come from the executors.** Both
//!   [`crate::exec::PjrtExecutor`] (real per-microbatch wall-clocks) and
//!   [`crate::exec::SimExecutor`] (the deterministic test double: exact
//!   analytic chunk times) tag every executed microbatch with a
//!   `(ParallelConfig, Observation)` pair in
//!   [`crate::exec::StepExecution::observations`].
//! * **A [`CalibrationStore`] accumulates them across steps**, one
//!   observation set per parallel configuration, and refits
//!   [`FittedCost`] incrementally via [`fit`] (least squares with column
//!   equilibration). Every refit bumps the store's *generation*.
//! * **Profiles persist as JSON** keyed by the analytic
//!   [`world_fingerprint`](crate::costmodel::world_fingerprint) of the
//!   `(model, cluster)` world they were measured on
//!   ([`CalibrationStore::save`] / [`CalibrationStore::load`]); a profile
//!   from a different world never attaches
//!   ([`CostModel::from_profile`](crate::costmodel::CostModel::from_profile)
//!   rejects it), and a corrupt file falls back to the analytic constants
//!   with a warning ([`load_profile_or_analytic`]).
//! * **Recalibration invalidates stale cost tables.** The attached
//!   [`CalibrationProfile`]'s generation and coefficients are folded into
//!   [`cost_fingerprint`](crate::costmodel::cost_fingerprint), which keys
//!   the shared [`CostTableLru`](crate::costmodel::CostTableLru) and the
//!   planning-session memo — a warm replan can never mix analytic and
//!   measured tables.
//!
//! Surfaces: `lobra calibrate` (sim-backed profiling run → profile JSON),
//! `lobra train --profile <path>` / `lobra plan --profile <path>` (plan
//! from measured times), `lobra train --save-profile <path>` (persist the
//! real run's in-situ observations), and `benches/calibration.rs` (fit
//! quality + analytic-vs-fitted divergence → `BENCH_calibration.json`).

use crate::cluster::ClusterSpec;
use crate::config::{ModelDesc, ParallelConfig};
use crate::costmodel::CostModel;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

use super::fnv1a;
use super::table::world_fingerprint;

/// Schema marker of the persisted profile JSON.
const PROFILE_KIND: &str = "lobra-calibration-profile";
/// Bump when the persisted schema changes incompatibly. Version 2 added
/// per-observation communication/bubble attribution and the device
/// fingerprint; version-1 profiles fitted raw wall-clocks (ascribing comm
/// and pipeline bubble to compute), so they are rejected rather than
/// silently reinterpreted.
const PROFILE_VERSION: u64 = 2;
/// Per-configuration observation cap: beyond this the store keeps a FIFO
/// ring of the most recent measurements. Bounds the resident memory and
/// the persisted JSON of arbitrarily long training runs (a 100k-step run
/// would otherwise accumulate millions of observations) while biasing the
/// fit toward *recent* steps — the ones past any warmup.
const MAX_OBS_PER_CONFIG: usize = 4096;

/// One profiled observation: a microbatch of `b` sequences × `s` tokens
/// took `seconds` of attributed wall time, of which `comm` went to TP/PP
/// collectives and `bubble` is this microbatch's share of the pipeline
/// fill/drain bubble. The fit regresses [`compute_seconds`]
/// (wall − comm − bubble) so multi-GPU measurements don't ascribe
/// communication or bubble time to the `t(b,s)` compute family.
///
/// [`compute_seconds`]: Observation::compute_seconds
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub b: u64,
    pub s: u64,
    /// Full attributed per-microbatch wall time (compute + comm + bubble
    /// share), seconds.
    pub seconds: f64,
    /// TP all-reduce + PP p2p seconds inside `seconds`.
    pub comm: f64,
    /// This microbatch's share of the pipeline bubble inside `seconds`.
    pub bubble: f64,
}

impl Observation {
    /// A single-device observation: the whole wall time is compute.
    pub fn new(b: u64, s: u64, seconds: f64) -> Self {
        Self { b, s, seconds, comm: 0.0, bubble: 0.0 }
    }

    /// A multi-GPU observation with explicit comm/bubble attribution.
    pub fn with_overheads(b: u64, s: u64, seconds: f64, comm: f64, bubble: f64) -> Self {
        Self { b, s, seconds, comm, bubble }
    }

    /// Wall time minus communication and bubble share — the quantity the
    /// `t(b,s)` family is fitted against (clamped at zero: attribution is
    /// measured too, so rounding can push the difference slightly
    /// negative).
    pub fn compute_seconds(&self) -> f64 {
        (self.seconds - self.comm - self.bubble).max(0.0)
    }
}

/// Fitted per-microbatch time model `t(b,s) = β₀ + β₁·b·s + β₂·b·s²`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FittedCost {
    pub beta0: f64,
    pub beta1: f64,
    pub beta2: f64,
}

impl FittedCost {
    /// Predicted microbatch *compute* seconds (comm and bubble are
    /// subtracted before fitting; the cost model re-adds its analytic
    /// communication terms on top of this prediction).
    pub fn predict(&self, b: u64, s: u64) -> f64 {
        let bs = (b * s) as f64;
        self.beta0 + self.beta1 * bs + self.beta2 * bs * s as f64
    }

    /// Relative RMS error against the observations' compute seconds;
    /// `None` when the set is empty (an empty set carries no evidence of
    /// fit quality — the old `0.0` return read as a *perfect* fit).
    pub fn rms_rel_error(&self, obs: &[Observation]) -> Option<f64> {
        if obs.is_empty() {
            return None;
        }
        let se: f64 = obs
            .iter()
            .map(|o| {
                let want = o.compute_seconds();
                let p = self.predict(o.b, o.s);
                let r = (p - want) / want.max(1e-12);
                r * r
            })
            .sum();
        Some((se / obs.len() as f64).sqrt())
    }
}

/// Least-squares fit of the 3-parameter model via the normal equations
/// (the design matrix is tiny: 3 columns).
///
/// Columns are equilibrated by their largest magnitude before forming
/// `AᵀA`: with sequence lengths up to 16K the raw `b·s²` column reaches
/// ~1e8 and squaring it would push the normal equations to ~1e16 condition,
/// destroying the constant term. Collinear observation sets (e.g. every
/// microbatch at one sequence length) are reported as `None` — the caller
/// keeps its analytic constants for that configuration.
pub fn fit(obs: &[Observation]) -> Option<FittedCost> {
    fit_impl(obs, false)
}

/// Relative least squares: each observation's row and target are scaled by
/// `1 / compute_seconds`, so every point contributes O(1) to the objective
/// and a wild outlier cannot bend the whole fit toward itself. Used as the
/// *ranking* fit inside [`fit_trimmed`] — the final coefficients still come
/// from the absolute fit on the surviving observations.
fn fit_weighted(obs: &[Observation]) -> Option<FittedCost> {
    fit_impl(obs, true)
}

fn fit_impl(obs: &[Observation], weighted: bool) -> Option<FittedCost> {
    if obs.len() < 3 {
        return None;
    }
    // columns: [1, b·s, b·s²]
    let rows: Vec<[f64; 3]> = obs
        .iter()
        .map(|o| {
            let bs = (o.b * o.s) as f64;
            [1.0, bs, bs * o.s as f64]
        })
        .collect();
    let mut scale = [0.0f64; 3];
    for row in &rows {
        for (sc, v) in scale.iter_mut().zip(row) {
            *sc = sc.max(v.abs());
        }
    }
    for sc in &mut scale {
        if *sc <= 0.0 {
            *sc = 1.0;
        }
    }
    // AᵀA (3x3) and Aᵀy over the equilibrated columns
    let mut ata = [[0.0f64; 3]; 3];
    let mut aty = [0.0f64; 3];
    for (row, o) in rows.iter().zip(obs) {
        let y = o.compute_seconds();
        let w = if weighted { 1.0 / y.max(1e-12) } else { 1.0 };
        let sr = [w * row[0] / scale[0], w * row[1] / scale[1], w * row[2] / scale[2]];
        for i in 0..3 {
            for j in 0..3 {
                ata[i][j] += sr[i] * sr[j];
            }
            aty[i] += sr[i] * w * y;
        }
    }
    // Singularity tolerance relative to the equilibrated matrix scale
    // (entries are O(n)): exact collinearity cancels to pivots of order
    // n·eps, far below this; genuinely diverse shapes sit far above.
    let tol = 1e-10 * obs.len() as f64;
    let beta = solve3(ata, aty, tol)?;
    let beta = [beta[0] / scale[0], beta[1] / scale[1], beta[2] / scale[2]];
    Some(FittedCost { beta0: beta[0].max(0.0), beta1: beta[1], beta2: beta[2] })
}

/// Solve a 3×3 linear system by Gaussian elimination with partial
/// pivoting; `None` when a pivot falls below `tol` (singular system).
fn solve3(mut a: [[f64; 3]; 3], mut y: [f64; 3], tol: f64) -> Option<[f64; 3]> {
    for col in 0..3 {
        // pivot
        let mut piv = col;
        for r in col + 1..3 {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < tol {
            return None;
        }
        a.swap(col, piv);
        y.swap(col, piv);
        // eliminate
        for r in col + 1..3 {
            let f = a[r][col] / a[col][col];
            for c in col..3 {
                a[r][c] -= f * a[col][c];
            }
            y[r] -= f * y[col];
        }
    }
    // back-substitute
    let mut x = [0.0f64; 3];
    for col in (0..3).rev() {
        let mut s = y[col];
        for c in col + 1..3 {
            s -= a[col][c] * x[c];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

/// Relative residual of one observation against a candidate fit.
fn rel_residual(f: &FittedCost, o: &Observation) -> f64 {
    let want = o.compute_seconds();
    ((f.predict(o.b, o.s) - want) / want.max(1e-12)).abs()
}

/// Trimmed least squares: rank observations by relative residual against a
/// robust (relative-weighted) fit, drop the `⌈trim_fraction·n⌉` worst, and
/// refit on the survivors. Real hardware produces occasional wild outliers
/// — a preempted kernel, a page-fault storm mid-step — and a plain
/// absolute least-squares fit chases them so hard that ranking residuals
/// against *it* would drop clean points instead (the outliers end up with
/// the smallest residuals); the 1/y-weighted ranking fit bounds each
/// point's influence, so the outliers surface.
///
/// Deterministic: residual ties break by observation index. Conservative:
/// trimming never leaves fewer than 4 points (below that the refit is as
/// noise-driven as the outliers were), and a survivor set that turns out
/// collinear falls back to the untrimmed fit rather than `None`.
pub fn fit_trimmed(obs: &[Observation], trim_fraction: f64) -> Option<FittedCost> {
    let base = fit(obs)?;
    if trim_fraction <= 0.0 {
        return Some(base);
    }
    let drop = (trim_fraction * obs.len() as f64).ceil() as usize;
    let keep_n = obs.len().saturating_sub(drop);
    if drop == 0 || keep_n < 4 {
        return Some(base);
    }
    let ranker = match fit_weighted(obs) {
        Some(r) => r,
        None => return Some(base),
    };
    let mut by_residual: Vec<usize> = (0..obs.len()).collect();
    by_residual.sort_by(|&i, &j| {
        let (ri, rj) = (rel_residual(&ranker, &obs[i]), rel_residual(&ranker, &obs[j]));
        rj.partial_cmp(&ri).unwrap_or(std::cmp::Ordering::Equal).then(i.cmp(&j))
    });
    let mut keep = by_residual.split_off(drop);
    keep.sort_unstable();
    let kept: Vec<Observation> = keep.iter().map(|&i| obs[i]).collect();
    fit(&kept).or(Some(base))
}

/// One configuration's accumulated measurements and (re)fitted model.
#[derive(Debug, Clone)]
pub struct ConfigCalibration {
    pub config: ParallelConfig,
    /// Bounded FIFO ring of the most recent [`MAX_OBS_PER_CONFIG`]
    /// measurements (ring order, not arrival order, once full).
    pub observations: Vec<Observation>,
    /// `None` until ≥3 shape-diverse observations arrive (underdetermined
    /// or collinear sets keep the analytic constants).
    pub fitted: Option<FittedCost>,
    /// Total measurements ever recorded (≥ `observations.len()`); drives
    /// the ring's replacement slot and survives persistence.
    pub recorded: u64,
    /// Warmup measurements dropped before the first kept one (see
    /// [`CalibrationStore::with_hygiene`]); session-local, not persisted.
    pub warmup_dropped: u64,
}

impl ConfigCalibration {
    /// Fit quality against this configuration's own observations.
    pub fn rms_rel_error(&self) -> Option<f64> {
        self.fitted.and_then(|f| f.rms_rel_error(&self.observations))
    }
}

/// Accumulates executor [`Observation`]s across steps, refits
/// [`FittedCost`] per configuration, and persists/loads the result as a
/// JSON profile keyed by the `(model, cluster)` [`world_fingerprint`].
///
/// The *generation* counter increments on every refit that absorbed new
/// observations; it is carried into the [`CalibrationProfile`] and from
/// there into [`cost_fingerprint`](crate::costmodel::cost_fingerprint), so
/// recalibration re-keys every cost table built from the profile.
#[derive(Debug, Clone)]
pub struct CalibrationStore {
    fingerprint: u64,
    /// [`DeviceProfile`](crate::cluster::DeviceProfile) fingerprint of the
    /// pool the measurements ran on: in a mixed fleet (`a100:16+h100:8`)
    /// each pool is its own measurement world and may not serve another
    /// pool's fits.
    device: u64,
    device_name: String,
    model: String,
    cluster: String,
    generation: u64,
    dirty: bool,
    /// First `warmup_discard` measurements per configuration are dropped
    /// (JIT compilation, allocator growth, cold caches).
    warmup_discard: u32,
    /// Fraction of worst-residual observations rejected per refit (see
    /// [`fit_trimmed`]).
    trim_fraction: f64,
    entries: Vec<ConfigCalibration>,
}

impl CalibrationStore {
    /// A store keyed to `cost`'s analytic `(model, cluster)` world. (An
    /// already-profiled cost model keys to the same world: fingerprints
    /// name what was *measured on*, not the measurement itself.)
    pub fn new(cost: &CostModel) -> Self {
        Self::for_world(&cost.model, &cost.cluster)
    }

    /// A store keyed to an explicit `(model, cluster)` world.
    pub fn for_world(model: &ModelDesc, cluster: &ClusterSpec) -> Self {
        Self {
            fingerprint: world_fingerprint(model, cluster),
            device: cluster.device.fingerprint(),
            device_name: cluster.device.name.clone(),
            model: model.name.clone(),
            cluster: cluster.name.clone(),
            generation: 0,
            dirty: false,
            warmup_discard: 0,
            trim_fraction: 0.0,
            entries: Vec::new(),
        }
    }

    /// Real-hardware measurement hygiene: discard the first
    /// `warmup_discard` measurements of every configuration (JIT, cold
    /// caches) and reject the worst `trim_fraction` of observations by
    /// relative residual at refit time. The defaults (`0`, `0.0`)
    /// preserve the exact old fit bit-for-bit; `trim_fraction` is clamped
    /// to `[0, 0.5]`.
    pub fn with_hygiene(mut self, warmup_discard: u32, trim_fraction: f64) -> Self {
        self.warmup_discard = warmup_discard;
        self.trim_fraction = if trim_fraction.is_finite() {
            trim_fraction.clamp(0.0, 0.5)
        } else {
            0.0
        };
        self
    }

    /// Analytic world fingerprint this store's measurements belong to.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Fingerprint of the device generation the measurements ran on.
    pub fn device_fingerprint(&self) -> u64 {
        self.device
    }

    /// Human-readable model name of the measured world.
    pub fn model(&self) -> &str {
        &self.model
    }

    /// Human-readable cluster name of the measured world.
    pub fn cluster(&self) -> &str {
        &self.cluster
    }

    /// Profile generation: bumped by every refit that saw new data.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Per-configuration calibrations, in first-seen order.
    pub fn entries(&self) -> &[ConfigCalibration] {
        &self.entries
    }

    /// Total recorded observations across configurations.
    pub fn n_observations(&self) -> usize {
        self.entries.iter().map(|e| e.observations.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record one single-device microbatch measurement (comm/bubble 0).
    pub fn record(&mut self, config: ParallelConfig, b: u64, s: u64, seconds: f64) {
        self.record_observation(config, Observation::new(b, s, seconds));
    }

    /// Record one microbatch measurement with full overhead attribution.
    /// Non-positive or non-finite durations (and negative or non-finite
    /// comm/bubble attributions) are dropped — a timer glitch must not
    /// poison the fit. The first [`Self::with_hygiene`] `warmup_discard`
    /// valid measurements per configuration are discarded; past
    /// [`MAX_OBS_PER_CONFIG`] per configuration, the oldest measurement
    /// is replaced (FIFO ring), keeping long runs bounded.
    pub fn record_observation(&mut self, config: ParallelConfig, obs: Observation) {
        if obs.b == 0
            || obs.s == 0
            || !obs.seconds.is_finite()
            || obs.seconds <= 0.0
            || !obs.comm.is_finite()
            || obs.comm < 0.0
            || !obs.bubble.is_finite()
            || obs.bubble < 0.0
        {
            return;
        }
        let i = match self.entries.iter().position(|e| e.config == config) {
            Some(i) => i,
            None => {
                self.entries.push(ConfigCalibration {
                    config,
                    observations: Vec::new(),
                    fitted: None,
                    recorded: 0,
                    warmup_dropped: 0,
                });
                self.entries.len() - 1
            }
        };
        let e = &mut self.entries[i];
        if e.warmup_dropped < self.warmup_discard as u64 {
            e.warmup_dropped += 1;
            return;
        }
        if e.observations.len() < MAX_OBS_PER_CONFIG {
            e.observations.push(obs);
        } else {
            let slot = (e.recorded % MAX_OBS_PER_CONFIG as u64) as usize;
            e.observations[slot] = obs;
        }
        e.recorded += 1;
        self.dirty = true;
    }

    /// Record a step's worth of executor observations
    /// ([`crate::exec::StepExecution::observations`]).
    pub fn record_all(&mut self, obs: &[(ParallelConfig, Observation)]) {
        for &(config, o) in obs {
            self.record_observation(config, o);
        }
    }

    /// Refit every configuration from its accumulated observations
    /// (trimmed least squares under [`Self::with_hygiene`]); bumps the
    /// generation when new observations arrived since the last fit.
    /// Returns the number of configurations with a usable fit.
    pub fn refit(&mut self) -> usize {
        if self.dirty {
            for e in &mut self.entries {
                e.fitted = fit_trimmed(&e.observations, self.trim_fraction);
            }
            self.generation += 1;
            self.dirty = false;
        }
        self.entries.iter().filter(|e| e.fitted.is_some()).count()
    }

    /// The current fit for `config`, if any (refit first to pick up new
    /// observations).
    pub fn fitted_for(&self, config: ParallelConfig) -> Option<FittedCost> {
        self.entries.iter().find(|e| e.config == config).and_then(|e| e.fitted)
    }

    /// Snapshot the fitted state as an attachable [`CalibrationProfile`]
    /// (refitting first if observations arrived since the last fit).
    pub fn profile(&mut self) -> CalibrationProfile {
        self.refit();
        CalibrationProfile {
            fingerprint: self.fingerprint,
            device: self.device,
            generation: self.generation,
            entries: self
                .entries
                .iter()
                .filter_map(|e| e.fitted.map(|f| (e.config, f)))
                .collect(),
        }
    }

    /// Serialize the full store (metadata, per-config fits *and* raw
    /// observations, so a later session can keep accumulating).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"kind\": \"{PROFILE_KIND}\",\n"));
        out.push_str(&format!("  \"version\": {PROFILE_VERSION},\n"));
        out.push_str(&format!("  \"model\": \"{}\",\n", self.model));
        out.push_str(&format!("  \"cluster\": \"{}\",\n", self.cluster));
        out.push_str(&format!("  \"device_name\": \"{}\",\n", self.device_name));
        out.push_str(&format!("  \"fingerprint\": \"{:016x}\",\n", self.fingerprint));
        out.push_str(&format!("  \"device\": \"{:016x}\",\n", self.device));
        out.push_str(&format!("  \"generation\": {},\n", self.generation));
        out.push_str("  \"configs\": [");
        for (i, e) in self.entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\n      \"tp\": {}, \"pp\": {}, \"recorded\": {},\n",
                e.config.tp, e.config.pp, e.recorded
            ));
            match e.fitted {
                Some(f) => out.push_str(&format!(
                    "      \"fit\": {{\"beta0\": {:?}, \"beta1\": {:?}, \"beta2\": {:?}}},\n",
                    f.beta0, f.beta1, f.beta2
                )),
                None => out.push_str("      \"fit\": null,\n"),
            }
            out.push_str("      \"observations\": [");
            for (k, o) in e.observations.iter().enumerate() {
                if k > 0 {
                    out.push(',');
                }
                out.push_str(&format!(
                    "\n        {{\"b\": {}, \"s\": {}, \"seconds\": {:?}, \
                     \"comm\": {:?}, \"bubble\": {:?}}}",
                    o.b, o.s, o.seconds, o.comm, o.bubble
                ));
            }
            if !e.observations.is_empty() {
                out.push_str("\n      ");
            }
            out.push_str("]\n    }");
        }
        if !self.entries.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("]\n}\n");
        out
    }

    /// Parse a store previously written by [`Self::to_json`]. Strict:
    /// wrong kind/version, a garbled fingerprint, or missing fields are
    /// errors (callers that want the analytic fallback use
    /// [`load_profile_or_analytic`]).
    pub fn from_json(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("invalid profile JSON: {e}"))?;
        let kind = j.get("kind").and_then(Json::as_str).unwrap_or("");
        if kind != PROFILE_KIND {
            return Err(anyhow!("not a calibration profile (kind {kind:?})"));
        }
        let version = j.get("version").and_then(Json::as_u64).unwrap_or(0);
        if version != PROFILE_VERSION {
            return Err(anyhow!("unsupported profile version {version}"));
        }
        let fp_hex = j
            .get("fingerprint")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("profile missing fingerprint"))?;
        let fingerprint = u64::from_str_radix(fp_hex, 16)
            .map_err(|_| anyhow!("bad profile fingerprint {fp_hex:?}"))?;
        let dev_hex = j
            .get("device")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("profile missing device fingerprint"))?;
        let device = u64::from_str_radix(dev_hex, 16)
            .map_err(|_| anyhow!("bad profile device fingerprint {dev_hex:?}"))?;
        let device_name =
            j.get("device_name").and_then(Json::as_str).unwrap_or("?").to_string();
        let generation = j
            .get("generation")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow!("profile missing generation"))?;
        let model = j.get("model").and_then(Json::as_str).unwrap_or("?").to_string();
        let cluster = j.get("cluster").and_then(Json::as_str).unwrap_or("?").to_string();
        let configs = j
            .get("configs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("profile missing configs"))?;
        let mut entries = Vec::with_capacity(configs.len());
        for c in configs {
            let tp = c
                .get("tp")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("config entry missing tp"))?;
            let pp = c
                .get("pp")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("config entry missing pp"))?;
            let config = ParallelConfig::new(tp as u32, pp as u32);
            let fitted = match c.get("fit") {
                None | Some(Json::Null) => None,
                Some(f) => Some(FittedCost {
                    beta0: f
                        .get("beta0")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("fit for {config} missing beta0"))?,
                    beta1: f
                        .get("beta1")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("fit for {config} missing beta1"))?,
                    beta2: f
                        .get("beta2")
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("fit for {config} missing beta2"))?,
                }),
            };
            let mut observations = Vec::new();
            if let Some(arr) = c.get("observations").and_then(Json::as_arr) {
                for o in arr {
                    observations.push(Observation {
                        b: o
                            .get("b")
                            .and_then(Json::as_u64)
                            .ok_or_else(|| anyhow!("observation missing b"))?,
                        s: o
                            .get("s")
                            .and_then(Json::as_u64)
                            .ok_or_else(|| anyhow!("observation missing s"))?,
                        seconds: o
                            .get("seconds")
                            .and_then(Json::as_f64)
                            .ok_or_else(|| anyhow!("observation missing seconds"))?,
                        comm: o.get("comm").and_then(Json::as_f64).unwrap_or(0.0),
                        bubble: o.get("bubble").and_then(Json::as_f64).unwrap_or(0.0),
                    });
                }
            }
            let recorded = c
                .get("recorded")
                .and_then(Json::as_u64)
                .unwrap_or(observations.len() as u64);
            entries.push(ConfigCalibration {
                config,
                observations,
                fitted,
                recorded,
                warmup_dropped: 0,
            });
        }
        Ok(Self {
            fingerprint,
            device,
            device_name,
            model,
            cluster,
            generation,
            dirty: false,
            warmup_discard: 0,
            trim_fraction: 0.0,
            entries,
        })
    }

    /// Write the store to `path` as JSON.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow!("could not write profile {path}: {e}"))
    }

    /// Load a store from `path`.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("could not read profile {path}: {e}"))?;
        Self::from_json(&text)
    }
}

/// Immutable fitted snapshot a [`CostModel`](crate::costmodel::CostModel)
/// plans against: per-configuration measured `t(b,s)` coefficients plus the
/// identity (world fingerprint, generation) that keys cost tables built
/// from it.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationProfile {
    fingerprint: u64,
    device: u64,
    generation: u64,
    entries: Vec<(ParallelConfig, FittedCost)>,
}

impl CalibrationProfile {
    /// Analytic world fingerprint the profile was measured on.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Fingerprint of the device generation the profile was measured on
    /// ([`DeviceProfile::fingerprint`](crate::cluster::DeviceProfile::fingerprint)).
    pub fn device_fingerprint(&self) -> u64 {
        self.device
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn n_configs(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Configurations with measured coefficients.
    pub fn configs(&self) -> impl Iterator<Item = ParallelConfig> + '_ {
        self.entries.iter().map(|&(c, _)| c)
    }

    /// Measured coefficients for `config`; configurations never profiled
    /// fall back to the analytic model.
    pub fn fitted_for(&self, config: ParallelConfig) -> Option<&FittedCost> {
        self.entries.iter().find(|(c, _)| *c == config).map(|(_, f)| f)
    }

    /// Fold the profile identity (generation + coefficients) into a cost
    /// fingerprint so recalibration re-keys every dependent cost table.
    pub(crate) fn fold_fingerprint(&self, mut h: u64) -> u64 {
        h = fnv1a(h, 0x9caf_11b7);
        h = fnv1a(h, self.device);
        h = fnv1a(h, self.generation);
        h = fnv1a(h, self.entries.len() as u64);
        for (cfg, f) in &self.entries {
            h = fnv1a(h, cfg.tp as u64);
            h = fnv1a(h, cfg.pp as u64);
            h = fnv1a(h, f.beta0.to_bits());
            h = fnv1a(h, f.beta1.to_bits());
            h = fnv1a(h, f.beta2.to_bits());
        }
        h
    }
}

/// Build the cost model for `(model, cluster)` from the profile at `path`,
/// falling back to the analytic constants with a warning when the file is
/// missing, corrupt, measured on a different world, or holds no usable
/// fit. The training/planning CLI must keep working when a profile rots —
/// silently planning garbage would be worse than planning analytically.
pub fn load_profile_or_analytic(
    path: &str,
    model: &ModelDesc,
    cluster: &ClusterSpec,
) -> CostModel {
    let attached = CalibrationStore::load(path)
        .and_then(|mut store| CostModel::from_profile(model, cluster, store.profile()));
    match attached {
        Ok(cost) => cost,
        Err(e) => {
            eprintln!("warning: {e}; falling back to the analytic cost model");
            CostModel::calibrated(model, cluster)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(beta: FittedCost, shapes: &[(u64, u64)]) -> Vec<Observation> {
        shapes
            .iter()
            .map(|&(b, s)| Observation::new(b, s, beta.predict(b, s)))
            .collect()
    }

    #[test]
    fn fit_recovers_exact_model() {
        let truth = FittedCost { beta0: 0.002, beta1: 3e-6, beta2: 2e-9 };
        let obs = synth(truth, &[(16, 64), (8, 128), (4, 256), (2, 512), (1, 1024), (32, 64)]);
        let f = fit(&obs).unwrap();
        assert!((f.beta0 - truth.beta0).abs() < 1e-6, "{f:?}");
        assert!((f.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6);
        assert!((f.beta2 - truth.beta2).abs() / truth.beta2 < 1e-6);
        assert!(f.rms_rel_error(&obs).unwrap() < 1e-9);
    }

    #[test]
    fn fit_survives_long_sequence_conditioning() {
        // pre-equilibration, b·s² up to 16K² pushed AᵀA to ~1e16 condition
        // and the recovered β₀ was garbage
        let truth = FittedCost { beta0: 0.004, beta1: 2.5e-6, beta2: 1.5e-9 };
        let obs = synth(
            truth,
            &[(32, 512), (8, 2048), (2, 8192), (1, 16384), (16, 512), (3, 2048), (1, 8192)],
        );
        let f = fit(&obs).unwrap();
        assert!((f.beta0 - truth.beta0).abs() / truth.beta0 < 1e-3, "{f:?}");
        for &(b, s) in &[(4u64, 1024u64), (1, 12288), (64, 256)] {
            let want = truth.predict(b, s);
            let got = f.predict(b, s);
            assert!((got - want).abs() / want < 1e-6, "({b},{s}): {got} vs {want}");
        }
    }

    #[test]
    fn fit_tolerates_noise() {
        let truth = FittedCost { beta0: 0.01, beta1: 5e-6, beta2: 1e-9 };
        let mut rng = crate::util::Rng::new(3);
        let obs: Vec<Observation> = [(16u64, 64u64), (8, 128), (4, 256), (2, 512), (8, 64), (4, 128), (2, 256), (1, 512)]
            .iter()
            .map(|&(b, s)| {
                Observation::new(b, s, truth.predict(b, s) * (1.0 + 0.05 * rng.normal()))
            })
            .collect();
        let f = fit(&obs).unwrap();
        assert!(f.rms_rel_error(&obs).unwrap() < 0.15);
        // prediction at an unseen shape within 20%
        let pred = f.predict(3, 384);
        let want = truth.predict(3, 384);
        assert!((pred - want).abs() / want < 0.2, "pred {pred} want {want}");
    }

    #[test]
    fn underdetermined_returns_none() {
        assert!(fit(&[Observation::new(1, 64, 0.1)]).is_none());
        // colinear observations (same b·s and b·s²) are singular
        let o = Observation::new(2, 128, 0.5);
        assert!(fit(&[o, o, o]).is_none());
    }

    #[test]
    fn empty_rms_is_none_not_perfect() {
        // regression: 0.0 for an empty set read as a perfect fit
        let f = FittedCost { beta0: 1.0, beta1: 1.0, beta2: 1.0 };
        assert_eq!(f.rms_rel_error(&[]), None);
    }

    #[test]
    fn store_records_fits_and_bumps_generation() {
        let truth = FittedCost { beta0: 0.003, beta1: 2e-6, beta2: 1e-9 };
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster);
        assert_eq!(store.generation(), 0);
        assert_eq!(store.refit(), 0, "refit without data must not bump");
        assert_eq!(store.generation(), 0);

        let cfg = ParallelConfig::new(2, 1);
        for &(b, s) in &[(16u64, 64u64), (8, 128), (4, 256), (2, 512), (32, 64)] {
            store.record(cfg, b, s, truth.predict(b, s));
        }
        assert_eq!(store.n_observations(), 5);
        assert_eq!(store.refit(), 1);
        assert_eq!(store.generation(), 1);
        let f = store.fitted_for(cfg).unwrap();
        assert!((f.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6);
        // refit with no new data: generation stable
        assert_eq!(store.refit(), 1);
        assert_eq!(store.generation(), 1);
        // one more observation → next refit bumps again
        store.record(cfg, 1, 1024, truth.predict(1, 1024));
        store.refit();
        assert_eq!(store.generation(), 2);
    }

    #[test]
    fn store_drops_garbage_measurements() {
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster);
        let cfg = ParallelConfig::new(1, 1);
        store.record(cfg, 0, 128, 0.5);
        store.record(cfg, 4, 128, -1.0);
        store.record(cfg, 4, 128, f64::NAN);
        store.record(cfg, 4, 0, 0.5);
        assert_eq!(store.n_observations(), 0);
        assert!(store.is_empty());
    }

    #[test]
    fn profile_lookup_and_fold() {
        let truth = FittedCost { beta0: 0.003, beta1: 2e-6, beta2: 1e-9 };
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster);
        let cfg = ParallelConfig::new(1, 1);
        for &(b, s) in &[(16u64, 64u64), (8, 128), (4, 256), (2, 512), (32, 64)] {
            store.record(cfg, b, s, truth.predict(b, s));
        }
        let p = store.profile();
        assert_eq!(p.n_configs(), 1);
        assert!(p.fitted_for(cfg).is_some());
        assert!(p.fitted_for(ParallelConfig::new(8, 1)).is_none());
        // folding is generation-sensitive
        let h1 = p.fold_fingerprint(0x1234);
        store.record(cfg, 1, 1024, truth.predict(1, 1024));
        let p2 = store.profile();
        assert_ne!(p.generation(), p2.generation());
        assert_ne!(h1, p2.fold_fingerprint(0x1234));
    }

    #[test]
    fn observation_ring_is_bounded() {
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster);
        let cfg = ParallelConfig::new(1, 1);
        let truth = FittedCost { beta0: 0.003, beta1: 2e-6, beta2: 1e-9 };
        let n = super::MAX_OBS_PER_CONFIG + 5;
        for i in 0..n {
            // cycle shapes so the final window still spans the model rank
            let (b, s) = [(16u64, 64u64), (8, 128), (4, 256), (2, 512), (32, 64)]
                [i % 5];
            store.record(cfg, b, s, truth.predict(b, s));
        }
        let e = &store.entries()[0];
        assert_eq!(e.observations.len(), super::MAX_OBS_PER_CONFIG);
        assert_eq!(e.recorded, n as u64);
        // the ring still fits (recent window is shape-diverse)
        store.refit();
        let f = store.fitted_for(cfg).unwrap();
        assert!((f.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6);
    }

    #[test]
    fn comm_and_bubble_are_subtracted_before_fitting() {
        // multi-GPU observations carry comm + bubble inside the wall time;
        // the fit must recover the *compute* family, not the wall family
        let truth = FittedCost { beta0: 0.002, beta1: 3e-6, beta2: 2e-9 };
        let obs: Vec<Observation> =
            [(16u64, 64u64), (8, 128), (4, 256), (2, 512), (1, 1024), (32, 64)]
                .iter()
                .map(|&(b, s)| {
                    let compute = truth.predict(b, s);
                    let comm = 0.5 * compute;
                    let bubble = 0.25 * compute;
                    Observation::with_overheads(b, s, compute + comm + bubble, comm, bubble)
                })
                .collect();
        let f = fit(&obs).unwrap();
        assert!((f.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6, "{f:?}");
        assert!((f.beta2 - truth.beta2).abs() / truth.beta2 < 1e-6, "{f:?}");
        assert!(f.rms_rel_error(&obs).unwrap() < 1e-9);
    }

    #[test]
    fn trimmed_fit_rejects_contaminated_observations() {
        // a contaminated observation set: two wild outliers (preemption,
        // page-fault storms) among clean measurements
        let truth = FittedCost { beta0: 0.003, beta1: 2e-6, beta2: 1e-9 };
        let mut obs = synth(
            truth,
            &[
                (16, 64),
                (8, 128),
                (4, 256),
                (2, 512),
                (1, 1024),
                (32, 64),
                (16, 128),
                (8, 256),
                (4, 512),
                (2, 1024),
            ],
        );
        obs[3].seconds *= 10.0;
        obs[7].seconds *= 25.0;
        let naive = fit(&obs).unwrap();
        assert!(
            (naive.beta1 - truth.beta1).abs() / truth.beta1 > 0.05,
            "outliers should visibly bend the naive fit: {naive:?}"
        );
        let trimmed = fit_trimmed(&obs, 0.2).unwrap();
        assert!((trimmed.beta0 - truth.beta0).abs() / truth.beta0 < 1e-6, "{trimmed:?}");
        assert!((trimmed.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6);
        assert!((trimmed.beta2 - truth.beta2).abs() / truth.beta2 < 1e-6);
    }

    #[test]
    fn trimmed_fit_defaults_preserve_plain_fit() {
        let truth = FittedCost { beta0: 0.002, beta1: 3e-6, beta2: 2e-9 };
        let obs = synth(truth, &[(16, 64), (8, 128), (4, 256), (2, 512), (1, 1024)]);
        let plain = fit(&obs).unwrap();
        let trimmed = fit_trimmed(&obs, 0.0).unwrap();
        assert_eq!(plain.beta0.to_bits(), trimmed.beta0.to_bits());
        assert_eq!(plain.beta1.to_bits(), trimmed.beta1.to_bits());
        assert_eq!(plain.beta2.to_bits(), trimmed.beta2.to_bits());
    }

    #[test]
    fn warmup_measurements_are_discarded() {
        let truth = FittedCost { beta0: 0.003, beta1: 2e-6, beta2: 1e-9 };
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster).with_hygiene(2, 0.0);
        let cfg = ParallelConfig::new(1, 1);
        // the first two measurements are contaminated by compilation; they
        // must never reach the fit
        store.record(cfg, 16, 64, 50.0 * truth.predict(16, 64));
        store.record(cfg, 8, 128, 50.0 * truth.predict(8, 128));
        for &(b, s) in &[(16u64, 64u64), (8, 128), (4, 256), (2, 512), (32, 64)] {
            store.record(cfg, b, s, truth.predict(b, s));
        }
        assert_eq!(store.n_observations(), 5);
        store.refit();
        let f = store.fitted_for(cfg).unwrap();
        assert!((f.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6, "{f:?}");
    }

    #[test]
    fn version1_profiles_are_rejected() {
        // v1 fitted raw wall-clocks; reinterpreting one as a v2 compute
        // fit would ascribe comm + bubble to compute
        let v1 = format!(
            "{{\n  \"kind\": \"{PROFILE_KIND}\",\n  \"version\": 1,\n  \
             \"model\": \"m\",\n  \"cluster\": \"c\",\n  \
             \"fingerprint\": \"00000000000000aa\",\n  \"generation\": 1,\n  \
             \"configs\": []\n}}\n"
        );
        let err = CalibrationStore::from_json(&v1).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn store_roundtrips_overheads_and_device() {
        let cluster = ClusterSpec::a100_40g(16);
        let model = ModelDesc::llama2_7b();
        let mut store = CalibrationStore::for_world(&model, &cluster);
        let cfg = ParallelConfig::new(2, 2);
        store.record_observation(cfg, Observation::with_overheads(4, 256, 0.5, 0.1, 0.05));
        let back = CalibrationStore::from_json(&store.to_json()).unwrap();
        assert_eq!(back.device_fingerprint(), store.device_fingerprint());
        assert_eq!(back.entries()[0].observations, store.entries()[0].observations);
    }

    #[test]
    fn quadratic_term_matters_for_long_sequences() {
        let f = FittedCost { beta0: 0.0, beta1: 1e-6, beta2: 1e-9 };
        // same token budget, longer sequences cost more (attention term)
        assert!(f.predict(1, 4096) > f.predict(16, 256));
    }
}
