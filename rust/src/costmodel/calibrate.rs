//! Profiling-based cost-model calibration (paper Appendix D methodology).
//!
//! The paper builds `t(b, s)` by offline-profiling real training steps and
//! fitting a function linear in `b` and quadratic in `s`:
//!
//! ```text
//! t(b, s) = β₀ + β₁·b·s + β₂·b·s²
//! ```
//!
//! (`β₁` captures the per-token dense work, `β₂` the attention term, `β₀`
//! fixed launch overhead.) This module provides the least-squares fit and a
//! [`ProfiledCost`] table the trainer can build from *real* PJRT step
//! measurements (`examples/e2e_train` / `Trainer`), closing the loop
//! between the L3 planner and the actual L1/L2 artifacts.

/// One profiled observation: a microbatch of `b` sequences × `s` tokens
/// took `seconds`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Observation {
    pub b: u64,
    pub s: u64,
    pub seconds: f64,
}

/// Fitted per-microbatch time model `t(b,s) = β₀ + β₁·b·s + β₂·b·s²`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FittedCost {
    pub beta0: f64,
    pub beta1: f64,
    pub beta2: f64,
}

impl FittedCost {
    /// Predicted microbatch seconds.
    pub fn predict(&self, b: u64, s: u64) -> f64 {
        let bs = (b * s) as f64;
        self.beta0 + self.beta1 * bs + self.beta2 * bs * s as f64
    }

    /// Relative RMS error over a set of observations.
    pub fn rms_rel_error(&self, obs: &[Observation]) -> f64 {
        if obs.is_empty() {
            return 0.0;
        }
        let se: f64 = obs
            .iter()
            .map(|o| {
                let p = self.predict(o.b, o.s);
                let r = (p - o.seconds) / o.seconds.max(1e-12);
                r * r
            })
            .sum();
        (se / obs.len() as f64).sqrt()
    }
}

/// Least-squares fit of the 3-parameter model via the normal equations
/// (the design matrix is tiny: 3 columns).
pub fn fit(obs: &[Observation]) -> Option<FittedCost> {
    if obs.len() < 3 {
        return None;
    }
    // columns: [1, b·s, b·s²]
    let rows: Vec<[f64; 3]> = obs
        .iter()
        .map(|o| {
            let bs = (o.b * o.s) as f64;
            [1.0, bs, bs * o.s as f64]
        })
        .collect();
    // AᵀA (3x3) and Aᵀy
    let mut ata = [[0.0f64; 3]; 3];
    let mut aty = [0.0f64; 3];
    for (row, o) in rows.iter().zip(obs) {
        for i in 0..3 {
            for j in 0..3 {
                ata[i][j] += row[i] * row[j];
            }
            aty[i] += row[i] * o.seconds;
        }
    }
    let beta = solve3(ata, aty)?;
    Some(FittedCost { beta0: beta[0].max(0.0), beta1: beta[1], beta2: beta[2] })
}

/// Solve a 3×3 linear system by Gaussian elimination with partial pivoting.
fn solve3(mut a: [[f64; 3]; 3], mut y: [f64; 3]) -> Option<[f64; 3]> {
    for col in 0..3 {
        // pivot
        let mut piv = col;
        for r in col + 1..3 {
            if a[r][col].abs() > a[piv][col].abs() {
                piv = r;
            }
        }
        if a[piv][col].abs() < 1e-18 {
            return None;
        }
        a.swap(col, piv);
        y.swap(col, piv);
        // eliminate
        for r in col + 1..3 {
            let f = a[r][col] / a[col][col];
            for c in col..3 {
                a[r][c] -= f * a[col][c];
            }
            y[r] -= f * y[col];
        }
    }
    // back-substitute
    let mut x = [0.0f64; 3];
    for col in (0..3).rev() {
        let mut s = y[col];
        for c in col + 1..3 {
            s -= a[col][c] * x[c];
        }
        x[col] = s / a[col][col];
    }
    Some(x)
}

/// A profiled per-microbatch cost table over a set of discrete shapes —
/// the live analogue of [`super::CostModel::t_microbatch`] for the real
/// (CPU-PJRT) executor. Built by timing the engine; consumed by the
/// trainer's virtual clock and the planner when planning for the local
/// runtime.
#[derive(Debug, Clone, Default)]
pub struct ProfiledCost {
    pub observations: Vec<Observation>,
    pub fitted: Option<FittedCost>,
}

impl ProfiledCost {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, b: u64, s: u64, seconds: f64) {
        self.observations.push(Observation { b, s, seconds });
        if self.observations.len() >= 3 {
            self.fitted = fit(&self.observations);
        }
    }

    /// Predict microbatch seconds; falls back to the nearest observation
    /// when the fit is not available yet.
    pub fn predict(&self, b: u64, s: u64) -> Option<f64> {
        if let Some(f) = self.fitted {
            return Some(f.predict(b, s));
        }
        self.observations
            .iter()
            .min_by_key(|o| (o.b as i64 - b as i64).abs() + (o.s as i64 - s as i64).abs())
            .map(|o| o.seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(beta: FittedCost, shapes: &[(u64, u64)]) -> Vec<Observation> {
        shapes
            .iter()
            .map(|&(b, s)| Observation { b, s, seconds: beta.predict(b, s) })
            .collect()
    }

    #[test]
    fn fit_recovers_exact_model() {
        let truth = FittedCost { beta0: 0.002, beta1: 3e-6, beta2: 2e-9 };
        let obs = synth(truth, &[(16, 64), (8, 128), (4, 256), (2, 512), (1, 1024), (32, 64)]);
        let f = fit(&obs).unwrap();
        assert!((f.beta0 - truth.beta0).abs() < 1e-6, "{f:?}");
        assert!((f.beta1 - truth.beta1).abs() / truth.beta1 < 1e-6);
        assert!((f.beta2 - truth.beta2).abs() / truth.beta2 < 1e-6);
        assert!(f.rms_rel_error(&obs) < 1e-9);
    }

    #[test]
    fn fit_tolerates_noise() {
        let truth = FittedCost { beta0: 0.01, beta1: 5e-6, beta2: 1e-9 };
        let mut rng = crate::util::Rng::new(3);
        let obs: Vec<Observation> = [(16u64, 64u64), (8, 128), (4, 256), (2, 512), (8, 64), (4, 128), (2, 256), (1, 512)]
            .iter()
            .map(|&(b, s)| Observation {
                b,
                s,
                seconds: truth.predict(b, s) * (1.0 + 0.05 * rng.normal()),
            })
            .collect();
        let f = fit(&obs).unwrap();
        assert!(f.rms_rel_error(&obs) < 0.15);
        // prediction at an unseen shape within 20%
        let pred = f.predict(3, 384);
        let want = truth.predict(3, 384);
        assert!((pred - want).abs() / want < 0.2, "pred {pred} want {want}");
    }

    #[test]
    fn underdetermined_returns_none() {
        assert!(fit(&[Observation { b: 1, s: 64, seconds: 0.1 }]).is_none());
        // colinear observations (same b·s and b·s²) are singular
        let o = Observation { b: 2, s: 128, seconds: 0.5 };
        assert!(fit(&[o, o, o]).is_none());
    }

    #[test]
    fn profiled_table_lifecycle() {
        let mut p = ProfiledCost::new();
        assert!(p.predict(4, 256).is_none());
        p.record(16, 64, 0.5);
        assert!(p.predict(4, 256).is_some()); // nearest fallback
        p.record(8, 128, 0.55);
        p.record(4, 256, 0.62);
        p.record(2, 512, 0.8);
        p.record(16, 128, 1.02); // break b·s colinearity
        assert!(p.fitted.is_some());
        let pred = p.predict(4, 256).unwrap();
        assert!(pred.is_finite() && pred > 0.0, "{pred}");
        assert!((pred - 0.62).abs() < 0.4, "{pred}");
    }

    #[test]
    fn quadratic_term_matters_for_long_sequences() {
        let f = FittedCost { beta0: 0.0, beta1: 1e-6, beta2: 1e-9 };
        // same token budget, longer sequences cost more (attention term)
        assert!(f.predict(1, 4096) > f.predict(16, 256));
    }
}
