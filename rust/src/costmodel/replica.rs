//! Replica-level load descriptions shared by the cost model and solver.

/// `d_j` sequences padded to `s_j` tokens, bound for one replica.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BucketLoad {
    pub count: u64,
    pub padded_len: u64,
}

/// How `d` sequences of one padded length chunk onto a replica
/// (Eq. 10's `d = m·b + r` decomposition).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkPlan {
    /// Sequences per full chunk (`b = ⌊M/s⌋`).
    pub per_chunk: u64,
    /// Number of full chunks (`m`).
    pub full_chunks: u64,
    /// Remainder chunk size (`r`, 0 = none).
    pub remainder: u64,
}

impl ChunkPlan {
    pub fn n_chunks(&self) -> u64 {
        self.full_chunks + (self.remainder > 0) as u64
    }

    pub fn total(&self) -> u64 {
        self.full_chunks * self.per_chunk + self.remainder
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_plan_arithmetic() {
        let p = ChunkPlan { per_chunk: 8, full_chunks: 3, remainder: 5 };
        assert_eq!(p.n_chunks(), 4);
        assert_eq!(p.total(), 29);
        let q = ChunkPlan { per_chunk: 8, full_chunks: 3, remainder: 0 };
        assert_eq!(q.n_chunks(), 3);
        assert_eq!(q.total(), 24);
    }
}
