//! Experiment metrics & reporting (the quantities the paper's figures plot).

/// Aggregate report over a joint-FT run.
#[derive(Debug, Clone, Default)]
pub struct JointFtReport {
    pub plan_notation: String,
    pub gpus: u32,
    pub steps: usize,
    /// Mean wall-clock per step (slowest replica + sync).
    pub mean_step_time: f64,
    /// Mean GPU·seconds per step — the paper's headline metric.
    pub gpu_seconds_per_step: f64,
    /// Std-dev of per-step GPU seconds.
    pub gpu_seconds_std: f64,
    /// Mean GPU utilization (busy / occupied).
    pub utilization: f64,
    /// Mean padding ratio of dispatched batches.
    pub mean_padding_ratio: f64,
    /// Mean per-step dispatch-solver time.
    pub mean_solve_seconds: f64,
}

impl JointFtReport {
    /// Build from per-step tuples
    /// `(step_time, gpu_seconds, utilization, padding_ratio, solve_seconds)`.
    pub fn from_steps<I>(plan_notation: &str, gpus: u32, steps: I) -> Self
    where
        I: IntoIterator<Item = (f64, f64, f64, f64, f64)>,
    {
        let rows: Vec<_> = steps.into_iter().collect();
        let n = rows.len().max(1) as f64;
        let sum = |f: fn(&(f64, f64, f64, f64, f64)) -> f64| -> f64 {
            rows.iter().map(f).sum::<f64>() / n
        };
        let mean_gs = sum(|r| r.1);
        let var_gs =
            rows.iter().map(|r| (r.1 - mean_gs).powi(2)).sum::<f64>() / n;
        Self {
            plan_notation: plan_notation.to_string(),
            gpus,
            steps: rows.len(),
            mean_step_time: sum(|r| r.0),
            gpu_seconds_per_step: mean_gs,
            gpu_seconds_std: var_gs.sqrt(),
            utilization: sum(|r| r.2),
            mean_padding_ratio: sum(|r| r.3),
            mean_solve_seconds: sum(|r| r.4),
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "plan=[{}] gpus={} steps={} step_time={:.3}s gpu_s/step={:.2} (±{:.2}) util={:.1}% pad={:.1}% solve={:.2}ms",
            self.plan_notation,
            self.gpus,
            self.steps,
            self.mean_step_time,
            self.gpu_seconds_per_step,
            self.gpu_seconds_std,
            self.utilization * 100.0,
            self.mean_padding_ratio * 100.0,
            self.mean_solve_seconds * 1e3,
        )
    }

    /// Relative reduction of this report's GPU seconds vs a baseline.
    pub fn reduction_vs(&self, baseline: &JointFtReport) -> f64 {
        if baseline.gpu_seconds_per_step <= 0.0 {
            return 0.0;
        }
        1.0 - self.gpu_seconds_per_step / baseline.gpu_seconds_per_step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let r = JointFtReport::from_steps(
            "x",
            16,
            vec![(1.0, 16.0, 0.9, 0.1, 0.001), (3.0, 48.0, 0.7, 0.3, 0.003)],
        );
        assert_eq!(r.steps, 2);
        assert!((r.mean_step_time - 2.0).abs() < 1e-12);
        assert!((r.gpu_seconds_per_step - 32.0).abs() < 1e-12);
        assert!((r.gpu_seconds_std - 16.0).abs() < 1e-12);
        assert!((r.utilization - 0.8).abs() < 1e-12);
    }

    #[test]
    fn reduction() {
        let a = JointFtReport { gpu_seconds_per_step: 50.0, ..Default::default() };
        let b = JointFtReport { gpu_seconds_per_step: 100.0, ..Default::default() };
        assert!((a.reduction_vs(&b) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_key_numbers() {
        let r = JointFtReport::from_steps("p", 8, vec![(1.0, 8.0, 1.0, 0.0, 0.0)]);
        let s = r.summary();
        assert!(s.contains("gpus=8"));
        assert!(s.contains("steps=1"));
    }
}
