//! Min–max dispatch solver (replaces the paper's SCIP/PuLP dependency).
//!
//! Both the per-step dispatch problem (paper Eq. 3: `p*` fixed) and the
//! inner problem of deployment planning (Eq. 2 with a candidate plan fixed)
//! reduce to the same structure:
//!
//! > `S` *groups* of identical replicas (group `i` = `p_i` replicas of one
//! > parallel configuration, supporting buckets `1..=r_i`), `R` *buckets*
//! > with demands `B_j`, and linear per-sequence costs `c_{ij}`; assign
//! > integer `d_{ij}` conserving demand so the slowest group finishes
//! > earliest: minimize `max_i [fixed_i + (Σ_j c_{ij} d_{ij}) / p_i]`.
//!
//! Three solvers, coarse-to-fine:
//!
//! * [`solve_length_based`] — the greedy baseline of Figure 4(c): every
//!   bucket goes entirely to its most efficient supporting group.
//! * [`solve_balanced`] — the production path: exact *fractional* optimum
//!   by parametric search on the makespan `t̂` (the greedy feasibility check
//!   is exact because Observation 1 makes the group preference order
//!   consistent across buckets), then integer rounding plus a local-search
//!   polish of single-sequence moves.
//! * [`bnb::solve_exact`] — branch-and-bound over `d_{ij}`, exponential but
//!   exact; used by proptest to certify `solve_balanced` on small instances.

pub mod bnb;
mod minmax;
pub mod partition;

pub use minmax::{solve_balanced, solve_fractional, solve_length_based};

/// One group of identical replicas in the dispatch problem.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupSpec {
    /// Per-sequence cost per bucket; `f64::INFINITY` where unsupported
    /// (bucket index beyond `r_i`).
    pub costs: Vec<f64>,
    /// `p_i` — number of replicas deployed with this configuration.
    pub replicas: u32,
    /// Fixed per-step cost of each replica (overheads, bubble estimate).
    pub fixed: f64,
}

impl GroupSpec {
    pub fn supports(&self, bucket: usize) -> bool {
        self.costs[bucket].is_finite()
    }
}

/// A dispatch problem instance.
#[derive(Debug, Clone, PartialEq)]
pub struct DispatchProblem {
    pub groups: Vec<GroupSpec>,
    /// `B_j` — sequences per bucket in the fused batch.
    pub demand: Vec<u64>,
}

impl DispatchProblem {
    pub fn n_buckets(&self) -> usize {
        self.demand.len()
    }

    /// Every bucket with demand must have at least one supporting group.
    pub fn is_satisfiable(&self) -> bool {
        self.demand.iter().enumerate().all(|(j, &b)| {
            b == 0 || self.groups.iter().any(|g| g.supports(j))
        })
    }
}

/// An integer assignment `d[group][bucket]` with its evaluated makespan.
#[derive(Debug, Clone, PartialEq)]
pub struct Assignment {
    pub d: Vec<Vec<u64>>,
    /// `max_i` group time under the linear model.
    pub makespan: f64,
}

impl Assignment {
    /// Check demand conservation and support constraints.
    pub fn is_feasible(&self, p: &DispatchProblem) -> bool {
        for (j, &b) in p.demand.iter().enumerate() {
            let total: u64 = self.d.iter().map(|row| row[j]).sum();
            if total != b {
                return false;
            }
        }
        for (i, g) in p.groups.iter().enumerate() {
            for (j, &dij) in self.d[i].iter().enumerate() {
                if dij > 0 && !g.supports(j) {
                    return false;
                }
            }
        }
        true
    }
}

/// Split one group's assignment row over its `p` replicas with an LPT
/// (longest-processing-time-first) greedy: buckets are handed out from the
/// most expensive down, each unit going to the currently lightest replica.
/// Returns per-replica per-bucket counts. This is the intra-group analogue
/// of the paper's `⌈d_{ij}/p_i⌉` — but load-aware, so a single long
/// sequence doesn't stack onto a replica that already carries extras.
pub fn split_group_lpt(costs: &[f64], row: &[u64], p: usize) -> Vec<Vec<u64>> {
    let p = p.max(1);
    let n_buckets = row.len();
    let mut shares = vec![vec![0u64; n_buckets]; p];
    let mut load = vec![0.0f64; p];
    // bucket order: descending per-sequence cost (finite only)
    let mut order: Vec<usize> = (0..n_buckets).filter(|&j| row[j] > 0).collect();
    order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap());
    for j in order {
        let c = costs[j];
        let d = row[j];
        // bulk-assign the even part, then LPT the remainder
        let base = d / p as u64;
        if base > 0 {
            for k in 0..p {
                shares[k][j] += base;
                load[k] += c * base as f64;
            }
        }
        for _ in 0..(d % p as u64) {
            let (k, _) = load
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            shares[k][j] += 1;
            load[k] += c;
        }
    }
    shares
}

/// Time of group `i` under assignment row `row`: replicas share the group's
/// sequences via the LPT split, and the group finishes when its most
/// loaded replica does.
pub fn group_time(g: &GroupSpec, row: &[u64]) -> f64 {
    if row.iter().all(|&d| d == 0) {
        return 0.0;
    }
    let shares = split_group_lpt(&g.costs, row, g.replicas as usize);
    let mut worst = 0.0f64;
    for rep in &shares {
        let t: f64 = rep
            .iter()
            .enumerate()
            .map(|(j, &s)| if s > 0 { g.costs[j] * s as f64 } else { 0.0 })
            .sum();
        worst = worst.max(t);
    }
    g.fixed + worst
}

/// Makespan of a full assignment.
pub fn makespan(p: &DispatchProblem, d: &[Vec<u64>]) -> f64 {
    p.groups
        .iter()
        .zip(d)
        .map(|(g, row)| group_time(g, row))
        // lint:allow(R5): f64::max is order-independent (no rounding drift).
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn simple_problem() -> DispatchProblem {
        DispatchProblem {
            groups: vec![
                GroupSpec { costs: vec![1.0, f64::INFINITY], replicas: 2, fixed: 0.0 },
                GroupSpec { costs: vec![1.5, 4.0], replicas: 1, fixed: 0.0 },
            ],
            demand: vec![10, 3],
        }
    }

    #[test]
    fn group_time_round_robin() {
        let g = GroupSpec { costs: vec![2.0], replicas: 2, fixed: 1.0 };
        // 5 sequences over 2 replicas: 3 and 2 → worst 3*2+1 = 7
        assert_eq!(group_time(&g, &[5]), 7.0);
        assert_eq!(group_time(&g, &[0]), 0.0);
    }

    #[test]
    fn feasibility_checks() {
        let p = simple_problem();
        let good = Assignment { d: vec![vec![10, 0], vec![0, 3]], makespan: 0.0 };
        assert!(good.is_feasible(&p));
        let bad_conservation = Assignment { d: vec![vec![9, 0], vec![0, 3]], makespan: 0.0 };
        assert!(!bad_conservation.is_feasible(&p));
        let bad_support = Assignment { d: vec![vec![9, 1], vec![1, 2]], makespan: 0.0 };
        assert!(!bad_support.is_feasible(&p));
    }

    #[test]
    fn satisfiability() {
        let mut p = simple_problem();
        assert!(p.is_satisfiable());
        p.groups[1].costs[1] = f64::INFINITY;
        assert!(!p.is_satisfiable());
        p.demand[1] = 0;
        assert!(p.is_satisfiable());
    }
}
