//! The fast min–max dispatch solvers: length-based greedy (baseline),
//! exact fractional via parametric makespan search, and the integer
//! rounding + local-search polish used on the per-step hot path.

use super::{group_time, makespan, Assignment, DispatchProblem};

/// Figure 4(c)'s baseline: dispatch each bucket to its length class — the
/// *least capable* group that still supports it (ties broken by cheaper
/// per-replica cost). This is "dispatch the training data to FT replicas
/// according to their lengths": short sequences go to the small replicas,
/// long sequences to the big ones, and nobody balances.
pub fn solve_length_based(p: &DispatchProblem) -> Option<Assignment> {
    // support range r_g = number of buckets the group can process
    let ranges: Vec<usize> = p
        .groups
        .iter()
        .map(|g| g.costs.iter().filter(|c| c.is_finite()).count())
        .collect();
    let mut d = vec![vec![0u64; p.n_buckets()]; p.groups.len()];
    for (j, &bj) in p.demand.iter().enumerate() {
        if bj == 0 {
            continue;
        }
        let best = p
            .groups
            .iter()
            .enumerate()
            .filter(|(_, g)| g.supports(j))
            .min_by(|(ia, a), (ib, b)| {
                let ca = a.costs[j] / a.replicas as f64;
                let cb = b.costs[j] / b.replicas as f64;
                ranges[*ia]
                    .cmp(&ranges[*ib])
                    .then(ca.total_cmp(&cb))
            })?
            .0;
        d[best][j] = bj;
    }
    let ms = makespan(p, &d);
    Some(Assignment { d, makespan: ms })
}

/// Exact *fractional* optimum via binary search on the makespan `t̂`.
///
/// Feasibility check for a fixed `t̂`: process buckets from last (longest,
/// fewest supporters — supports are nested: `supports(j) ⊆ supports(j')`
/// for `j > j'`) to first, assigning each bucket greedily to its cheapest
/// supporting groups with remaining capacity `(t̂ − fixed_i)·p_i`. Because
/// the cost model satisfies Observation 1, the group preference order is
/// identical for every bucket, making the greedy exchange-optimal.
///
/// Returns `(t_star, fractional d)`.
pub fn solve_fractional(p: &DispatchProblem) -> Option<(f64, Vec<Vec<f64>>)> {
    if !p.is_satisfiable() {
        return None;
    }
    let feasible = |t_hat: f64| -> Option<Vec<Vec<f64>>> {
        let mut d = vec![vec![0f64; p.n_buckets()]; p.groups.len()];
        let mut cap: Vec<f64> = p
            .groups
            .iter()
            .map(|g| ((t_hat - g.fixed).max(0.0)) * g.replicas as f64)
            .collect();
        for j in (0..p.n_buckets()).rev() {
            let mut need = p.demand[j] as f64;
            if need == 0.0 {
                continue;
            }
            // cheapest groups first
            let mut order: Vec<usize> = (0..p.groups.len())
                .filter(|&i| p.groups[i].supports(j))
                .collect();
            order.sort_by(|&a, &b| {
                p.groups[a].costs[j].total_cmp(&p.groups[b].costs[j])
            });
            for i in order {
                if need <= 1e-12 {
                    break;
                }
                let c = p.groups[i].costs[j];
                if c <= 0.0 {
                    d[i][j] += need;
                    need = 0.0;
                    break;
                }
                let take = (cap[i] / c).min(need);
                if take > 0.0 {
                    d[i][j] += take;
                    cap[i] -= take * c;
                    need -= take;
                }
            }
            if need > 1e-9 {
                return None;
            }
        }
        Some(d)
    };

    // Upper bound: everything on the single cheapest feasible layout —
    // use the length-based assignment's makespan as a safe upper bound.
    let ub0 = solve_length_based(p)?.makespan.max(1e-9);
    let (mut lo, mut hi) = (0.0f64, ub0);
    if feasible(hi).is_none() {
        // fixed costs can make length-based evaluation and capacity model
        // diverge slightly; grow until feasible.
        let mut h = hi;
        for _ in 0..64 {
            h *= 2.0;
            if feasible(h).is_some() {
                hi = h;
                break;
            }
        }
        feasible(hi)?;
    }
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if feasible(mid).is_some() {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    let d = feasible(hi)?;
    Some((hi, d))
}

/// Production solver: fractional optimum → largest-remainder integer
/// rounding (conserving each bucket's demand) → local search moving single
/// sequences off the critical group while it improves the makespan.
pub fn solve_balanced(p: &DispatchProblem) -> Option<Assignment> {
    if !p.is_satisfiable() {
        return None;
    }
    let (_, frac) = solve_fractional(p)?;
    let n_groups = p.groups.len();
    let n_buckets = p.n_buckets();

    // Largest-remainder rounding per bucket.
    let mut d = vec![vec![0u64; n_buckets]; n_groups];
    for j in 0..n_buckets {
        let bj = p.demand[j];
        if bj == 0 {
            continue;
        }
        let mut floors = 0u64;
        let mut rem: Vec<(f64, usize)> = Vec::with_capacity(n_groups);
        for i in 0..n_groups {
            let f = frac[i][j];
            let fl = f.floor() as u64;
            d[i][j] = fl;
            floors += fl;
            rem.push((f - fl as f64, i));
        }
        let mut short = bj.saturating_sub(floors);
        // Hand the leftovers to the largest fractional parts (cheapest on tie).
        rem.sort_by(|a, b| b.0.total_cmp(&a.0));
        let mut k = 0;
        while short > 0 {
            let (_, i) = rem[k % rem.len()];
            if p.groups[i].supports(j) {
                d[i][j] += 1;
                short -= 1;
            }
            k += 1;
            if k > 4 * n_groups && short > 0 {
                // fall back: any supporting group
                for (ii, row) in d.iter_mut().enumerate() {
                    if p.groups[ii].supports(j) && short > 0 {
                        row[j] += 1;
                        short -= 1;
                    }
                }
            }
        }
        // Rounding may have overshot if floors already exceeded demand
        // (cannot happen with exact fractional conservation, but guard).
        let mut total: u64 = (0..n_groups).map(|i| d[i][j]).sum();
        let mut i = 0;
        while total > bj {
            if d[i % n_groups][j] > 0 {
                d[i % n_groups][j] -= 1;
                total -= 1;
            }
            i += 1;
        }
    }

    local_search(p, &mut d, 10_000);
    let ms = makespan(p, &d);
    Some(Assignment { d, makespan: ms })
}

/// Hill-climb: repeatedly move one sequence out of the *critical* group
/// (the one attaining the makespan) to the destination minimizing the new
/// makespan; stop when no move improves or the move budget runs out.
fn local_search(p: &DispatchProblem, d: &mut [Vec<u64>], budget: usize) {
    let n_groups = p.groups.len();
    let times = |d: &[Vec<u64>]| -> Vec<f64> {
        p.groups.iter().zip(d).map(|(g, row)| group_time(g, row)).collect()
    };
    let mut t = times(d);
    for _ in 0..budget {
        let Some((crit, &crit_t)) =
            t.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1))
        else {
            break; // no groups: nothing to improve
        };
        // Moves of k ∈ {1, 2, 4, ...} sequences — bulk moves escape the
        // plateaus where shifting one sequence cannot reduce a replica's
        // ceiling (counts below the group's replica count).
        let mut best: Option<(usize, usize, u64, f64)> = None; // (bucket, dst, k, new_max)
        for j in 0..p.n_buckets() {
            if d[crit][j] == 0 {
                continue;
            }
            for dst in 0..n_groups {
                if dst == crit || !p.groups[dst].supports(j) {
                    continue;
                }
                let mut k = 1u64;
                loop {
                    let k_eff = k.min(d[crit][j]);
                    // simulate the move
                    d[crit][j] -= k_eff;
                    d[dst][j] += k_eff;
                    let tc = group_time(&p.groups[crit], &d[crit]);
                    let td = group_time(&p.groups[dst], &d[dst]);
                    d[crit][j] += k_eff;
                    d[dst][j] -= k_eff;
                    let others = t
                        .iter()
                        .enumerate()
                        .filter(|&(i, _)| i != crit && i != dst)
                        .map(|(_, &x)| x)
                        // lint:allow(R5): f64::max is order-independent (no rounding drift).
                        .fold(0.0f64, f64::max);
                    let new_max = tc.max(td).max(others);
                    if new_max + 1e-12 < crit_t
                        && best.map_or(true, |(_, _, _, m)| new_max < m)
                    {
                        best = Some((j, dst, k_eff, new_max));
                    }
                    if k >= d[crit][j] {
                        break;
                    }
                    k *= 2;
                }
            }
        }
        if let Some((j, dst, k, _)) = best {
            d[crit][j] -= k;
            d[dst][j] += k;
            t[crit] = group_time(&p.groups[crit], &d[crit]);
            t[dst] = group_time(&p.groups[dst], &d[dst]);
            continue;
        }
        // No single move improves: try 1-for-1 swaps with the critical
        // group (move a j-sequence out, take a j2-sequence back) — escapes
        // integer-granularity local optima on small batches.
        let mut best_swap: Option<(usize, usize, usize, f64)> = None;
        for j in 0..p.n_buckets() {
            if d[crit][j] == 0 {
                continue;
            }
            for dst in 0..n_groups {
                if dst == crit || !p.groups[dst].supports(j) {
                    continue;
                }
                for j2 in 0..p.n_buckets() {
                    if j2 == j || d[dst][j2] == 0 || !p.groups[crit].supports(j2) {
                        continue;
                    }
                    d[crit][j] -= 1;
                    d[dst][j] += 1;
                    d[dst][j2] -= 1;
                    d[crit][j2] += 1;
                    let tc = group_time(&p.groups[crit], &d[crit]);
                    let td = group_time(&p.groups[dst], &d[dst]);
                    d[crit][j] += 1;
                    d[dst][j] -= 1;
                    d[dst][j2] += 1;
                    d[crit][j2] -= 1;
                    let others = t
                        .iter()
                        .enumerate()
                        .filter(|&(i, _)| i != crit && i != dst)
                        .map(|(_, &x)| x)
                        // lint:allow(R5): f64::max is order-independent (no rounding drift).
                        .fold(0.0f64, f64::max);
                    let new_max = tc.max(td).max(others);
                    if new_max + 1e-12 < crit_t
                        && best_swap.map_or(true, |(_, _, _, m)| new_max < m)
                    {
                        best_swap = Some((j, dst, j2, new_max));
                    }
                }
            }
        }
        match best_swap {
            Some((j, dst, j2, _)) => {
                d[crit][j] -= 1;
                d[dst][j] += 1;
                d[dst][j2] -= 1;
                d[crit][j2] += 1;
                t[crit] = group_time(&p.groups[crit], &d[crit]);
                t[dst] = group_time(&p.groups[dst], &d[dst]);
            }
            None => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::GroupSpec;

    fn problem(groups: Vec<GroupSpec>, demand: Vec<u64>) -> DispatchProblem {
        DispatchProblem { groups, demand }
    }

    fn two_group() -> DispatchProblem {
        problem(
            vec![
                GroupSpec { costs: vec![1.0, f64::INFINITY], replicas: 1, fixed: 0.0 },
                GroupSpec { costs: vec![2.0, 6.0], replicas: 1, fixed: 0.0 },
            ],
            vec![12, 2],
        )
    }

    #[test]
    fn length_based_routes_to_cheapest() {
        let p = two_group();
        let a = solve_length_based(&p).unwrap();
        assert!(a.is_feasible(&p));
        // bucket 0 all on group 0 (cheapest), bucket 1 forced to group 1
        assert_eq!(a.d[0][0], 12);
        assert_eq!(a.d[1][1], 2);
        assert_eq!(a.makespan, 12.0);
    }

    #[test]
    fn balanced_beats_length_based() {
        let p = two_group();
        let lb = solve_length_based(&p).unwrap();
        let bal = solve_balanced(&p).unwrap();
        assert!(bal.is_feasible(&p));
        assert!(bal.makespan <= lb.makespan + 1e-9);
        // optimum: move short sequences to group 1 until balanced:
        // g0: x, g1: 2*(12-x)+12 → x≈8.6.. integer: ~9 vs 2*3+12=18? no:
        // bucket1 cost 6*2=12 on g1; moving k shorts to g1: g0=12-k,
        // g1=12+2k → balance at k=0 g0=12 g1=12. Already equal!
        assert!(bal.makespan <= 12.0 + 1e-9);
    }

    #[test]
    fn balanced_migrates_under_skew() {
        // Heavy skew: many short sequences, one big long-capable group.
        let p = problem(
            vec![
                GroupSpec { costs: vec![1.0, f64::INFINITY], replicas: 4, fixed: 0.0 },
                GroupSpec { costs: vec![1.5, 10.0], replicas: 1, fixed: 0.0 },
            ],
            vec![101, 2],
        );
        let lb = solve_length_based(&p).unwrap();
        let bal = solve_balanced(&p).unwrap();
        // length-based: g0 gets all 101 shorts → ⌈101/4⌉ = 26; g1 → 20.0
        assert!((lb.makespan - 26.0).abs() < 1e-9);
        // balanced should push some shorts to g1
        assert!(bal.makespan < lb.makespan);
        assert!(bal.d[1][0] > 0, "no migration happened: {:?}", bal.d);
    }

    #[test]
    fn fractional_lower_bounds_integer() {
        let p = two_group();
        let (t_frac, _) = solve_fractional(&p).unwrap();
        let bal = solve_balanced(&p).unwrap();
        assert!(t_frac <= bal.makespan + 1e-6);
    }

    #[test]
    fn unsatisfiable_returns_none() {
        let p = problem(
            vec![GroupSpec { costs: vec![1.0, f64::INFINITY], replicas: 1, fixed: 0.0 }],
            vec![5, 1],
        );
        assert!(solve_balanced(&p).is_none());
        assert!(solve_length_based(&p).is_none());
    }

    #[test]
    fn zero_demand_is_trivial() {
        let p = problem(
            vec![GroupSpec { costs: vec![1.0], replicas: 1, fixed: 0.5 }],
            vec![0],
        );
        let a = solve_balanced(&p).unwrap();
        assert_eq!(a.makespan, 0.0);
    }

    #[test]
    fn respects_fixed_costs() {
        // Group 1 has a huge fixed cost; balanced should prefer group 0.
        let p = problem(
            vec![
                GroupSpec { costs: vec![1.0], replicas: 1, fixed: 0.0 },
                GroupSpec { costs: vec![1.0], replicas: 1, fixed: 100.0 },
            ],
            vec![10],
        );
        let a = solve_balanced(&p).unwrap();
        assert_eq!(a.d[0][0], 10, "{:?}", a.d);
    }

    #[test]
    fn multi_replica_group_shares_load() {
        let p = problem(
            vec![GroupSpec { costs: vec![1.0], replicas: 4, fixed: 0.0 }],
            vec![10],
        );
        let a = solve_balanced(&p).unwrap();
        // 10 over 4 replicas → ceil = 3
        assert!((a.makespan - 3.0).abs() < 1e-9);
    }
}
