//! Deployment-plan enumeration: integer partitions of the GPU budget over
//! candidate parallel configurations (paper Appendix A, step 2: "construct
//! possible deployment plans ... formulated as an integer partition
//! problem").

use crate::config::ParallelConfig;

/// One candidate deployment plan: `counts[i]` replicas of `configs[i]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Plan {
    pub counts: Vec<u32>,
}

impl Plan {
    pub fn gpus_used(&self, configs: &[ParallelConfig]) -> u32 {
        self.counts
            .iter()
            .zip(configs)
            .map(|(&c, cfg)| c * cfg.n())
            .sum()
    }

    pub fn n_replicas(&self) -> u32 {
        self.counts.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }
}

/// Enumerate all plans with `min_gpus <= Σ p_i·n_i <= n_gpus`.
///
/// `require_longest`: if `Some(idx)`, every plan must deploy at least one
/// replica of configuration `idx` (the one able to process the longest
/// bucket — otherwise the dispatch problem is unsatisfiable, so such plans
/// are dead on arrival and enumerating them wastes planner time).
/// `max_plans` caps the enumeration as a safety valve.
pub fn enumerate_plans(
    configs: &[ParallelConfig],
    n_gpus: u32,
    min_gpus: u32,
    require_longest: Option<usize>,
    max_plans: usize,
) -> Vec<Plan> {
    let mut out = Vec::new();
    let mut counts = vec![0u32; configs.len()];
    fn dfs(
        configs: &[ParallelConfig],
        i: usize,
        remaining: u32,
        counts: &mut Vec<u32>,
        out: &mut Vec<Plan>,
        n_gpus: u32,
        min_gpus: u32,
        require_longest: Option<usize>,
        max_plans: usize,
    ) {
        if out.len() >= max_plans {
            return;
        }
        if i == configs.len() {
            let used = n_gpus - remaining;
            if used >= min_gpus {
                if let Some(li) = require_longest {
                    if counts[li] == 0 {
                        return;
                    }
                }
                if counts.iter().any(|&c| c > 0) {
                    out.push(Plan { counts: counts.clone() });
                }
            }
            return;
        }
        let n = configs[i].n();
        let max_count = remaining / n;
        for c in 0..=max_count {
            counts[i] = c;
            dfs(
                configs,
                i + 1,
                remaining - c * n,
                counts,
                out,
                n_gpus,
                min_gpus,
                require_longest,
                max_plans,
            );
            if out.len() >= max_plans {
                break;
            }
        }
        counts[i] = 0;
    }
    dfs(
        configs,
        0,
        n_gpus,
        &mut counts,
        &mut out,
        n_gpus,
        min_gpus,
        require_longest,
        max_plans,
    );
    out
}

/// Count plans without materializing them (for Table 5 style reporting).
pub fn count_plans(configs: &[ParallelConfig], n_gpus: u32, min_gpus: u32) -> u64 {
    // DP over gpu budget: ways[g] with configs as item types (unbounded
    // counts, order-insensitive by processing one config at a time).
    let mut ways = vec![0u64; n_gpus as usize + 1];
    ways[0] = 1;
    for cfg in configs {
        let n = cfg.n() as usize;
        for g in n..=n_gpus as usize {
            ways[g] = ways[g].saturating_add(ways[g - n]);
        }
    }
    ways[min_gpus as usize..=n_gpus as usize]
        .iter()
        .fold(0u64, |a, &b| a.saturating_add(b))
        .saturating_sub(if min_gpus == 0 { 1 } else { 0 }) // exclude empty plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfgs() -> Vec<ParallelConfig> {
        vec![
            ParallelConfig::new(1, 1),
            ParallelConfig::new(2, 1),
            ParallelConfig::new(4, 1),
        ]
    }

    #[test]
    fn enumerates_exact_partitions() {
        // N=4, configs {1,2,4}: partitions of 4 into parts {1,2,4}:
        // 1+1+1+1, 1+1+2, 2+2, 4 → 4 plans
        let plans = enumerate_plans(&cfgs(), 4, 4, None, 10_000);
        assert_eq!(plans.len(), 4, "{plans:?}");
        for p in &plans {
            assert_eq!(p.gpus_used(&cfgs()), 4);
        }
    }

    #[test]
    fn min_gpus_allows_slack() {
        let all = enumerate_plans(&cfgs(), 4, 1, None, 10_000);
        let exact = enumerate_plans(&cfgs(), 4, 4, None, 10_000);
        assert!(all.len() > exact.len());
    }

    #[test]
    fn require_longest_filters() {
        let plans = enumerate_plans(&cfgs(), 4, 4, Some(2), 10_000);
        for p in &plans {
            assert!(p.counts[2] >= 1);
        }
        // only 1×4 fits with the 4-GPU config mandatory at N=4
        assert_eq!(plans.len(), 1);
    }

    #[test]
    fn count_matches_enumeration() {
        let plans = enumerate_plans(&cfgs(), 8, 0, None, 100_000);
        let counted = count_plans(&cfgs(), 8, 0);
        assert_eq!(plans.len() as u64, counted);
    }

    #[test]
    fn max_plans_caps() {
        let plans = enumerate_plans(&cfgs(), 16, 0, None, 5);
        assert_eq!(plans.len(), 5);
    }
}
