//! Deployment-plan enumeration: integer partitions of the GPU budget over
//! candidate parallel configurations (paper Appendix A, step 2: "construct
//! possible deployment plans ... formulated as an integer partition
//! problem").
//!
//! The enumeration is *streaming*: [`visit_plans`] walks the DFS over
//! per-config replica counts and hands each admissible count vector to a
//! visitor, so callers can score-and-discard plans on the fly (the planner
//! fuses the Theorem-1 lower-bound filter into the visitor) instead of
//! materializing millions of `Plan`s. [`dfs_prefixes`] splits the top of
//! the search tree into independent subtrees for parallel traversal with
//! [`visit_plans_from`]; traversing the prefixes in order reproduces the
//! exact sequential DFS order, which keeps parallel searches deterministic.
//! [`enumerate_plans`] remains as the collecting wrapper.

use crate::config::ParallelConfig;
use crate::util::par::CancelToken;

/// One candidate deployment plan: `counts[i]` replicas of `configs[i]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Plan {
    pub counts: Vec<u32>,
}

impl Plan {
    pub fn gpus_used(&self, configs: &[ParallelConfig]) -> u32 {
        self.counts
            .iter()
            .zip(configs)
            .map(|(&c, cfg)| c * cfg.n())
            .sum()
    }

    pub fn n_replicas(&self) -> u32 {
        self.counts.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }
}

/// DFS over counts for configs `i..`; calls `visit` at admissible leaves.
/// The visitor returns `false` to stop the whole search; `dfs` propagates
/// that as its own return value.
fn dfs<F: FnMut(&[u32]) -> bool>(
    configs: &[ParallelConfig],
    i: usize,
    remaining: u32,
    counts: &mut [u32],
    n_gpus: u32,
    min_gpus: u32,
    require_longest: Option<usize>,
    visit: &mut F,
) -> bool {
    if i == configs.len() {
        let used = n_gpus - remaining;
        if used < min_gpus {
            return true;
        }
        if let Some(li) = require_longest {
            if counts[li] == 0 {
                return true;
            }
        }
        if counts.iter().all(|&c| c == 0) {
            return true;
        }
        return visit(counts);
    }
    let n = configs[i].n();
    for c in 0..=remaining / n {
        counts[i] = c;
        if !dfs(
            configs,
            i + 1,
            remaining - c * n,
            counts,
            n_gpus,
            min_gpus,
            require_longest,
            visit,
        ) {
            return false;
        }
    }
    counts[i] = 0;
    true
}

/// Streaming enumeration of all plans with `min_gpus <= Σ p_i·n_i <= n_gpus`.
///
/// `visit` receives each admissible plan's count vector in DFS order
/// (counts of config 0 ascending outermost) and returns `false` to stop
/// early (e.g. a plan cap). Returns `false` iff the search was stopped.
///
/// `require_longest`: if `Some(idx)`, every visited plan deploys at least
/// one replica of configuration `idx` (the one able to process the longest
/// bucket — otherwise the dispatch problem is unsatisfiable, so such plans
/// are dead on arrival and enumerating them wastes planner time).
pub fn visit_plans<F: FnMut(&[u32]) -> bool>(
    configs: &[ParallelConfig],
    n_gpus: u32,
    min_gpus: u32,
    require_longest: Option<usize>,
    visit: &mut F,
) -> bool {
    let mut counts = vec![0u32; configs.len()];
    dfs(
        configs,
        0,
        n_gpus,
        &mut counts,
        n_gpus,
        min_gpus,
        require_longest,
        visit,
    )
}

/// Like [`visit_plans`] but with the counts of configs `0..prefix.len()`
/// pinned to `prefix` — one independent subtree of the DFS. Prefixes whose
/// pinned counts already exceed the GPU budget visit nothing.
pub fn visit_plans_from<F: FnMut(&[u32]) -> bool>(
    configs: &[ParallelConfig],
    prefix: &[u32],
    n_gpus: u32,
    min_gpus: u32,
    require_longest: Option<usize>,
    visit: &mut F,
) -> bool {
    debug_assert!(prefix.len() <= configs.len());
    let used: u32 = prefix.iter().zip(configs).map(|(&c, cfg)| c * cfg.n()).sum();
    if used > n_gpus {
        return true;
    }
    let mut counts = vec![0u32; configs.len()];
    counts[..prefix.len()].copy_from_slice(prefix);
    dfs(
        configs,
        prefix.len(),
        n_gpus - used,
        &mut counts,
        n_gpus,
        min_gpus,
        require_longest,
        visit,
    )
}

/// DFS over configs `i..` visiting only leaves strictly *after* `after` in
/// DFS (lexicographic) order. `tight` is true while `counts[..i] ==
/// after[..i]`; a tight branch starts its loop at `after[i]` (its subtree
/// contains the checkpoint), every other branch enumerates freely. The
/// tight leaf — the checkpoint itself — is skipped.
#[allow(clippy::too_many_arguments)]
fn dfs_after<F: FnMut(&[u32]) -> bool>(
    configs: &[ParallelConfig],
    i: usize,
    remaining: u32,
    counts: &mut [u32],
    after: &[u32],
    tight: bool,
    n_gpus: u32,
    min_gpus: u32,
    require_longest: Option<usize>,
    visit: &mut F,
) -> bool {
    if i == configs.len() {
        if tight {
            return true; // the checkpoint itself: already visited
        }
        let used = n_gpus - remaining;
        if used < min_gpus {
            return true;
        }
        if let Some(li) = require_longest {
            if counts[li] == 0 {
                return true;
            }
        }
        if counts.iter().all(|&c| c == 0) {
            return true;
        }
        return visit(counts);
    }
    let n = configs[i].n();
    let lo = if tight { after[i] } else { 0 };
    let mut c = lo;
    while c <= remaining / n {
        counts[i] = c;
        if !dfs_after(
            configs,
            i + 1,
            remaining - c * n,
            counts,
            after,
            tight && c == after[i],
            n_gpus,
            min_gpus,
            require_longest,
            visit,
        ) {
            return false;
        }
        c += 1;
    }
    counts[i] = 0;
    true
}

/// Resume the [`visit_plans`] enumeration strictly after the checkpoint
/// count vector `after` (a previously visited plan): visits exactly the
/// suffix of the full DFS order that follows `after`. This is the
/// building block for resumable capped searches — a planning session whose
/// search tripped the `max_plans` cap records the last enumerated vector
/// and continues from it on the next budget instead of re-walking the
/// prefix. Returns `false` iff the visitor stopped the search.
pub fn visit_plans_after<F: FnMut(&[u32]) -> bool>(
    configs: &[ParallelConfig],
    after: &[u32],
    n_gpus: u32,
    min_gpus: u32,
    require_longest: Option<usize>,
    visit: &mut F,
) -> bool {
    assert_eq!(after.len(), configs.len(), "checkpoint arity mismatch");
    let mut counts = vec![0u32; configs.len()];
    dfs_after(
        configs,
        0,
        n_gpus,
        &mut counts,
        after,
        true,
        n_gpus,
        min_gpus,
        require_longest,
        visit,
    )
}

/// Expand the top levels of the enumeration tree into at least
/// `target_items` independent DFS subtrees (count prefixes, all of equal
/// depth). Traversing the prefixes in order with [`visit_plans_from`]
/// reproduces the exact [`visit_plans`] DFS order, so a parallel fold over
/// the prefixes that merges results in prefix order stays deterministic.
pub fn dfs_prefixes(
    configs: &[ParallelConfig],
    n_gpus: u32,
    target_items: usize,
) -> Vec<Vec<u32>> {
    let mut items: Vec<Vec<u32>> = vec![Vec::new()];
    let mut depth = 0;
    while depth < configs.len() && items.len() < target_items {
        let n = configs[depth].n();
        let mut next = Vec::with_capacity(items.len() * 2);
        for prefix in &items {
            let used: u32 =
                prefix.iter().zip(configs).map(|(&c, cfg)| c * cfg.n()).sum();
            let remaining = n_gpus - used;
            for c in 0..=remaining / n {
                let mut p = prefix.clone();
                p.push(c);
                next.push(p);
            }
        }
        items = next;
        depth += 1;
    }
    items
}

/// Budget-sliced enumeration cursor over the [`visit_plans`] DFS order:
/// between slices it holds the last visited count vector as a checkpoint,
/// and the next slice resumes strictly after it via [`visit_plans_after`]
/// — no prefix re-walking. The concatenation of the slices is exactly the
/// full DFS order for *any* budget schedule (property-tested below) —
/// the resumption contract anytime replans rely on.
///
/// Two ways to drive it: [`Self::slice`] walks the enumeration directly
/// (self-contained budget-sliced visiting); the planning session's
/// anytime search instead runs its slices through the planner's fused
/// top-K machinery (which embeds the same `visit_plans_after` resumption)
/// and uses the cursor as the checkpoint/exhaustion bookkeeper
/// ([`Self::set_checkpoint`] / [`Self::finish`]) between slices.
#[derive(Debug, Clone, Default)]
pub struct PlanCursor {
    /// Last visited count vector (`None` until the first slice runs).
    checkpoint: Option<Vec<u32>>,
    /// The underlying enumeration ran to completion.
    exhausted: bool,
}

impl PlanCursor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Whether the enumeration has been fully walked.
    pub fn is_exhausted(&self) -> bool {
        self.exhausted
    }

    /// The resume checkpoint (last visited count vector), if any.
    pub fn checkpoint(&self) -> Option<&[u32]> {
        self.checkpoint.as_deref()
    }

    /// Adopt a checkpoint recorded by an external walk of the same
    /// enumeration (e.g. a capped search's `resume` vector).
    pub fn set_checkpoint(&mut self, checkpoint: Vec<u32>) {
        self.checkpoint = Some(checkpoint);
    }

    /// Mark the enumeration complete (no further slices will visit).
    pub fn finish(&mut self) {
        self.exhausted = true;
    }

    /// Visit up to `budget` further plans, advancing the cursor. Returns
    /// the number of plans visited this slice; after it, either
    /// [`Self::is_exhausted`] is true (the walk completed) or the
    /// checkpoint points at the last visited plan. A visitor returning
    /// `false` also ends the slice (the cursor stays resumable at the last
    /// visited plan).
    pub fn slice<F: FnMut(&[u32]) -> bool>(
        &mut self,
        configs: &[ParallelConfig],
        n_gpus: u32,
        min_gpus: u32,
        require_longest: Option<usize>,
        budget: usize,
        visit: &mut F,
    ) -> usize {
        self.slice_cancellable(configs, n_gpus, min_gpus, require_longest, budget, None, visit)
    }

    /// [`Self::slice`] with a supersession check: `cancel`, when armed,
    /// ends the slice *before the next visit* — a superseding event
    /// interrupts an in-flight slice mid-walk instead of waiting for its
    /// budget to run out. The cursor stays resumable at the last visited
    /// plan and is never marked exhausted by a cancellation, but callers
    /// that cancel are expected to discard the search: the set of plans
    /// the interrupted slice visited depends on *when* the flag was
    /// observed, so partial results are nondeterministic by construction.
    #[allow(clippy::too_many_arguments)]
    pub fn slice_cancellable<F: FnMut(&[u32]) -> bool>(
        &mut self,
        configs: &[ParallelConfig],
        n_gpus: u32,
        min_gpus: u32,
        require_longest: Option<usize>,
        budget: usize,
        cancel: Option<&CancelToken>,
        visit: &mut F,
    ) -> usize {
        if self.exhausted || budget == 0 {
            return 0;
        }
        if matches!(cancel, Some(c) if c.is_cancelled()) {
            return 0;
        }
        let mut seen = 0usize;
        let mut last: Option<Vec<u32>> = None;
        let mut wrapped = |counts: &[u32]| -> bool {
            if seen >= budget {
                return false;
            }
            if matches!(cancel, Some(c) if c.is_cancelled()) {
                return false;
            }
            seen += 1;
            match &mut last {
                Some(l) => {
                    l.clear();
                    l.extend_from_slice(counts);
                }
                None => last = Some(counts.to_vec()),
            }
            visit(counts)
        };
        let complete = match &self.checkpoint {
            None => visit_plans(configs, n_gpus, min_gpus, require_longest, &mut wrapped),
            Some(after) => visit_plans_after(
                configs,
                after,
                n_gpus,
                min_gpus,
                require_longest,
                &mut wrapped,
            ),
        };
        if let Some(l) = last {
            self.checkpoint = Some(l);
        }
        if complete {
            self.exhausted = true;
        }
        seen
    }
}

/// Collecting wrapper over [`visit_plans`]: materialize up to `max_plans`
/// plans (the cap is a safety valve against runaway enumerations).
pub fn enumerate_plans(
    configs: &[ParallelConfig],
    n_gpus: u32,
    min_gpus: u32,
    require_longest: Option<usize>,
    max_plans: usize,
) -> Vec<Plan> {
    let mut out = Vec::new();
    if max_plans == 0 {
        return out;
    }
    visit_plans(configs, n_gpus, min_gpus, require_longest, &mut |counts| {
        out.push(Plan { counts: counts.to_vec() });
        out.len() < max_plans
    });
    out
}

/// Deterministically slice `total` GPUs across planning shards: each shard
/// `i` receives at least `mins[i]` GPUs (its feasibility floor — enough for
/// the smallest config supporting its longest sequence), and the remaining
/// capacity is split proportionally to `loads` by floor + largest-remainder
/// rounding (remainder ties broken toward the lower shard index). Shards
/// with non-positive load get only their floor; if *every* load is
/// non-positive the spare capacity stays unassigned (slices still sum to
/// ≤ `total`).
///
/// Returns `None` when the floors alone exceed `total` (the fleet cannot
/// be partitioned feasibly) or on a `loads`/`mins` arity mismatch.
pub fn capacity_slices(total: u32, loads: &[f64], mins: &[u32]) -> Option<Vec<u32>> {
    if loads.len() != mins.len() {
        return None;
    }
    let floor_sum: u64 = mins.iter().map(|&m| m as u64).sum();
    if floor_sum > total as u64 {
        return None;
    }
    let mut out: Vec<u32> = mins.to_vec();
    let spare = total - floor_sum as u32;
    // lint:allow(R5): fixed left-to-right sum in deterministic shard-index order.
    let load_sum: f64 = loads.iter().filter(|l| l.is_finite() && **l > 0.0).sum();
    if spare == 0 || load_sum <= 0.0 {
        return Some(out);
    }
    // Floor of each proportional share, then hand leftovers to the largest
    // fractional remainders (ties to the lower index — sort is stable).
    let shares: Vec<f64> = loads
        .iter()
        .map(|&l| {
            if l.is_finite() && l > 0.0 {
                spare as f64 * l / load_sum
            } else {
                0.0
            }
        })
        .collect();
    let mut given = 0u32;
    let mut rem: Vec<(usize, f64)> = Vec::with_capacity(shares.len());
    for (i, &s) in shares.iter().enumerate() {
        let fl = (s.floor() as u32).min(spare - given);
        out[i] += fl;
        given += fl;
        rem.push((i, s - s.floor()));
    }
    rem.sort_by(|a, b| b.1.total_cmp(&a.1));
    for (i, _) in rem {
        if given >= spare {
            break;
        }
        out[i] += 1;
        given += 1;
    }
    Some(out)
}

/// Count plans without materializing them (for Table 5 style reporting).
pub fn count_plans(configs: &[ParallelConfig], n_gpus: u32, min_gpus: u32) -> u64 {
    // DP over gpu budget: ways[g] with configs as item types (unbounded
    // counts, order-insensitive by processing one config at a time).
    let mut ways = vec![0u64; n_gpus as usize + 1];
    ways[0] = 1;
    for cfg in configs {
        let n = cfg.n() as usize;
        for g in n..=n_gpus as usize {
            ways[g] = ways[g].saturating_add(ways[g - n]);
        }
    }
    ways[min_gpus as usize..=n_gpus as usize]
        .iter()
        .fold(0u64, |a, &b| a.saturating_add(b))
        .saturating_sub(if min_gpus == 0 { 1 } else { 0 }) // exclude empty plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfgs() -> Vec<ParallelConfig> {
        vec![
            ParallelConfig::new(1, 1),
            ParallelConfig::new(2, 1),
            ParallelConfig::new(4, 1),
        ]
    }

    #[test]
    fn enumerates_exact_partitions() {
        // N=4, configs {1,2,4}: partitions of 4 into parts {1,2,4}:
        // 1+1+1+1, 1+1+2, 2+2, 4 → 4 plans
        let plans = enumerate_plans(&cfgs(), 4, 4, None, 10_000);
        assert_eq!(plans.len(), 4, "{plans:?}");
        for p in &plans {
            assert_eq!(p.gpus_used(&cfgs()), 4);
        }
    }

    #[test]
    fn min_gpus_allows_slack() {
        let all = enumerate_plans(&cfgs(), 4, 1, None, 10_000);
        let exact = enumerate_plans(&cfgs(), 4, 4, None, 10_000);
        assert!(all.len() > exact.len());
    }

    #[test]
    fn require_longest_filters() {
        let plans = enumerate_plans(&cfgs(), 4, 4, Some(2), 10_000);
        for p in &plans {
            assert!(p.counts[2] >= 1);
        }
        // only 1×4 fits with the 4-GPU config mandatory at N=4
        assert_eq!(plans.len(), 1);
    }

    #[test]
    fn count_matches_enumeration() {
        let plans = enumerate_plans(&cfgs(), 8, 0, None, 100_000);
        let counted = count_plans(&cfgs(), 8, 0);
        assert_eq!(plans.len() as u64, counted);
    }

    #[test]
    fn max_plans_caps() {
        let plans = enumerate_plans(&cfgs(), 16, 0, None, 5);
        assert_eq!(plans.len(), 5);
    }

    #[test]
    fn visitor_matches_collector() {
        let mut visited: Vec<Vec<u32>> = Vec::new();
        let complete = visit_plans(&cfgs(), 8, 4, None, &mut |c| {
            visited.push(c.to_vec());
            true
        });
        assert!(complete);
        let collected: Vec<Vec<u32>> = enumerate_plans(&cfgs(), 8, 4, None, usize::MAX)
            .into_iter()
            .map(|p| p.counts)
            .collect();
        assert_eq!(visited, collected);
    }

    #[test]
    fn visitor_early_stop() {
        let mut n = 0;
        let complete = visit_plans(&cfgs(), 16, 0, None, &mut |_| {
            n += 1;
            n < 5
        });
        assert!(!complete);
        assert_eq!(n, 5);
    }

    #[test]
    fn resume_after_checkpoint_yields_exact_suffix() {
        let mut full: Vec<Vec<u32>> = Vec::new();
        visit_plans(&cfgs(), 8, 4, None, &mut |c| {
            full.push(c.to_vec());
            true
        });
        assert!(full.len() > 3);
        // resuming after the k-th visited plan must yield plans k+1.. exactly
        for k in [0usize, 1, full.len() / 2, full.len() - 1] {
            let mut resumed: Vec<Vec<u32>> = Vec::new();
            visit_plans_after(&cfgs(), &full[k], 8, 4, None, &mut |c| {
                resumed.push(c.to_vec());
                true
            });
            assert_eq!(resumed, full[k + 1..].to_vec(), "checkpoint {k}");
        }
    }

    #[test]
    fn resume_respects_filters_and_early_stop() {
        let mut full: Vec<Vec<u32>> = Vec::new();
        visit_plans(&cfgs(), 8, 4, Some(2), &mut |c| {
            full.push(c.to_vec());
            true
        });
        assert!(full.len() >= 2, "{full:?}");
        let mut resumed: Vec<Vec<u32>> = Vec::new();
        visit_plans_after(&cfgs(), &full[0], 8, 4, Some(2), &mut |c| {
            resumed.push(c.to_vec());
            true
        });
        assert_eq!(resumed, full[1..].to_vec());
        // early stop propagates like visit_plans
        let mut n = 0;
        let complete = visit_plans_after(&cfgs(), &full[0], 8, 0, None, &mut |_| {
            n += 1;
            n < 3
        });
        assert!(!complete);
        assert_eq!(n, 3);
    }

    #[test]
    fn cursor_slices_concatenate_to_full_dfs_order() {
        let mut full: Vec<Vec<u32>> = Vec::new();
        visit_plans(&cfgs(), 8, 4, None, &mut |c| {
            full.push(c.to_vec());
            true
        });
        assert!(full.len() > 5);
        // any budget schedule must reproduce the exact DFS order
        for schedule in [vec![1usize; 64], vec![3, 1, 5, 2, 100], vec![full.len()], vec![2, 2]] {
            let mut cursor = PlanCursor::new();
            let mut seen: Vec<Vec<u32>> = Vec::new();
            let mut total = 0usize;
            for &budget in &schedule {
                if cursor.is_exhausted() {
                    break;
                }
                let n = cursor.slice(&cfgs(), 8, 4, None, budget, &mut |c| {
                    seen.push(c.to_vec());
                    true
                });
                assert!(n <= budget);
                total += n;
            }
            // run to exhaustion with a generous tail budget
            while !cursor.is_exhausted() {
                total += cursor.slice(&cfgs(), 8, 4, None, 1_000, &mut |c| {
                    seen.push(c.to_vec());
                    true
                });
            }
            assert_eq!(seen, full, "schedule {schedule:?}");
            assert_eq!(total, full.len());
            // an exhausted cursor visits nothing more
            assert_eq!(cursor.slice(&cfgs(), 8, 4, None, 10, &mut |_| true), 0);
        }
    }

    #[test]
    fn cursor_respects_filters_and_adopted_checkpoints() {
        let mut full: Vec<Vec<u32>> = Vec::new();
        visit_plans(&cfgs(), 8, 4, Some(2), &mut |c| {
            full.push(c.to_vec());
            true
        });
        assert!(full.len() >= 2);
        // a cursor handed an external checkpoint resumes strictly after it
        let mut cursor = PlanCursor::new();
        cursor.set_checkpoint(full[0].clone());
        let mut seen: Vec<Vec<u32>> = Vec::new();
        while !cursor.is_exhausted() {
            cursor.slice(&cfgs(), 8, 4, Some(2), 1, &mut |c| {
                seen.push(c.to_vec());
                true
            });
        }
        assert_eq!(seen, full[1..].to_vec());
        assert_eq!(cursor.checkpoint(), Some(&full[full.len() - 1][..]));
    }

    #[test]
    fn cancelled_slice_stops_early_and_stays_resumable() {
        let mut full: Vec<Vec<u32>> = Vec::new();
        visit_plans(&cfgs(), 8, 4, None, &mut |c| {
            full.push(c.to_vec());
            true
        });
        assert!(full.len() > 5);
        // cancel mid-slice (after 3 visits): the slice ends before the
        // next visit even though its budget allows the full walk
        let mut cursor = PlanCursor::new();
        let token = CancelToken::new();
        let mut seen: Vec<Vec<u32>> = Vec::new();
        let n = cursor.slice_cancellable(&cfgs(), 8, 4, None, 1_000, Some(&token), &mut |c| {
            seen.push(c.to_vec());
            if seen.len() == 3 {
                token.cancel();
            }
            true
        });
        assert_eq!(n, 3);
        assert_eq!(seen, full[..3].to_vec());
        assert!(!cursor.is_exhausted());
        assert_eq!(cursor.checkpoint(), Some(&full[2][..]));
        // an armed token means later slices visit nothing at all
        assert_eq!(
            cursor.slice_cancellable(&cfgs(), 8, 4, None, 10, Some(&token), &mut |_| true),
            0
        );
        // a fresh (un-cancelled) resume picks up strictly after the
        // checkpoint: slices still concatenate to the full DFS order
        while !cursor.is_exhausted() {
            cursor.slice_cancellable(&cfgs(), 8, 4, None, 2, None, &mut |c| {
                seen.push(c.to_vec());
                true
            });
        }
        assert_eq!(seen, full);
    }

    #[test]
    fn capacity_slices_respects_floors_and_total() {
        let s = capacity_slices(16, &[1.0, 3.0], &[2, 2]).unwrap();
        assert_eq!(s.iter().sum::<u32>(), 16);
        assert!(s[0] >= 2 && s[1] >= 2);
        // 12 spare split 1:3 → 3 and 9
        assert_eq!(s, vec![5, 11]);
        // floors alone exceeding the total is infeasible
        assert!(capacity_slices(3, &[1.0, 1.0], &[2, 2]).is_none());
        // arity mismatch is an error, not a panic
        assert!(capacity_slices(8, &[1.0], &[1, 1]).is_none());
    }

    #[test]
    fn capacity_slices_largest_remainder_ties_to_lower_index() {
        // 5 spare over equal loads: floors 1 each, remainders equal →
        // the extra GPU goes to shard 0
        let s = capacity_slices(5, &[1.0, 1.0, 1.0], &[0, 0, 0]).unwrap();
        assert_eq!(s, vec![2, 2, 1]);
        // determinism: same inputs, same slices
        assert_eq!(s, capacity_slices(5, &[1.0, 1.0, 1.0], &[0, 0, 0]).unwrap());
    }

    #[test]
    fn capacity_slices_zero_load_gets_only_floor() {
        let s = capacity_slices(10, &[0.0, 4.0], &[1, 1]).unwrap();
        assert_eq!(s, vec![1, 9]);
        // all-zero loads: spare stays unassigned, floors kept
        let s = capacity_slices(10, &[0.0, 0.0], &[1, 2]).unwrap();
        assert_eq!(s, vec![1, 2]);
    }

    #[test]
    fn prefixes_partition_the_search() {
        let mut full: Vec<Vec<u32>> = Vec::new();
        visit_plans(&cfgs(), 8, 4, None, &mut |c| {
            full.push(c.to_vec());
            true
        });
        for target in [1usize, 2, 4, 32, 1000] {
            let prefixes = dfs_prefixes(&cfgs(), 8, target);
            let mut seq: Vec<Vec<u32>> = Vec::new();
            for p in &prefixes {
                visit_plans_from(&cfgs(), p, 8, 4, None, &mut |c| {
                    seq.push(c.to_vec());
                    true
                });
            }
            assert_eq!(seq, full, "target {target}");
        }
    }
}
