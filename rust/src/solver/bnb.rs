//! Exact branch-and-bound reference solver for the min–max dispatch ILP.
//!
//! Exponential in `Σ_j B_j` — only usable on small instances. Its role is
//! certification: proptest compares [`super::solve_balanced`] against this
//! on randomized small problems (see rust/tests/solver_equivalence.rs), the
//! same way the paper validates its heuristics against un-pruned SCIP runs
//! (Appendix B.2, Table 5).

use super::{group_time, makespan, Assignment, DispatchProblem};

/// Exact solver. `node_budget` caps explored nodes (returns best found).
pub fn solve_exact(p: &DispatchProblem, node_budget: usize) -> Option<Assignment> {
    if !p.is_satisfiable() {
        return None;
    }
    let n_groups = p.groups.len();
    let n_buckets = p.n_buckets();

    // Seed incumbent with the heuristic solution (also a correctness aid:
    // B&B can only improve on it).
    let mut best = super::solve_balanced(p)?;
    let mut d = vec![vec![0u64; n_buckets]; n_groups];
    let mut nodes = 0usize;

    // Assign buckets from last (fewest supporters) to first; within a
    // bucket, enumerate compositions of B_j over supporting groups.
    fn recurse(
        p: &DispatchProblem,
        j: isize,
        d: &mut Vec<Vec<u64>>,
        best: &mut Assignment,
        nodes: &mut usize,
        budget: usize,
    ) {
        if *nodes >= budget {
            return;
        }
        *nodes += 1;
        if j < 0 {
            let ms = makespan(p, d);
            if ms < best.makespan {
                *best = Assignment { d: d.clone(), makespan: ms };
            }
            return;
        }
        let jj = j as usize;
        let bj = p.demand[jj];
        let supporters: Vec<usize> = (0..p.groups.len())
            .filter(|&i| p.groups[i].supports(jj))
            .collect();
        if bj == 0 {
            recurse(p, j - 1, d, best, nodes, budget);
            return;
        }
        // prune: partial makespan of already-assigned buckets
        let partial = p
            .groups
            .iter()
            .zip(d.iter())
            .map(|(g, row)| group_time(g, row))
            // lint:allow(R5): f64::max is order-independent (no rounding drift).
            .fold(0.0f64, f64::max);
        if partial >= best.makespan {
            return;
        }
        // enumerate compositions of bj over supporters
        fn compositions(
            p: &DispatchProblem,
            jj: usize,
            remaining: u64,
            k: usize,
            supporters: &[usize],
            d: &mut Vec<Vec<u64>>,
            j: isize,
            best: &mut Assignment,
            nodes: &mut usize,
            budget: usize,
        ) {
            if *nodes >= budget {
                return;
            }
            if k == supporters.len() - 1 {
                let i = supporters[k];
                d[i][jj] = remaining;
                recurse(p, j - 1, d, best, nodes, budget);
                d[i][jj] = 0;
                return;
            }
            let i = supporters[k];
            for take in 0..=remaining {
                d[i][jj] = take;
                compositions(p, jj, remaining - take, k + 1, supporters, d, j, best, nodes, budget);
            }
            d[i][jj] = 0;
        }
        compositions(p, jj, bj, 0, &supporters, d, j, best, nodes, budget);
    }

    recurse(p, n_buckets as isize - 1, &mut d, &mut best, &mut nodes, node_budget);
    Some(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::GroupSpec;

    #[test]
    fn exact_finds_known_optimum() {
        // 2 groups, bucket of 4: costs 1.0 vs 1.0 → optimum splits 2/2.
        let p = DispatchProblem {
            groups: vec![
                GroupSpec { costs: vec![1.0], replicas: 1, fixed: 0.0 },
                GroupSpec { costs: vec![1.0], replicas: 1, fixed: 0.0 },
            ],
            demand: vec![4],
        };
        let a = solve_exact(&p, 1_000_000).unwrap();
        assert_eq!(a.makespan, 2.0);
        assert!(a.is_feasible(&p));
    }

    #[test]
    fn exact_no_worse_than_heuristic() {
        let p = DispatchProblem {
            groups: vec![
                GroupSpec { costs: vec![1.0, f64::INFINITY], replicas: 2, fixed: 0.0 },
                GroupSpec { costs: vec![1.3, 5.0], replicas: 1, fixed: 0.1 },
            ],
            demand: vec![9, 2],
        };
        let h = crate::solver::solve_balanced(&p).unwrap();
        let e = solve_exact(&p, 1_000_000).unwrap();
        assert!(e.makespan <= h.makespan + 1e-9);
        assert!(e.is_feasible(&p));
    }

    #[test]
    fn respects_budget() {
        let p = DispatchProblem {
            groups: vec![
                GroupSpec { costs: vec![1.0; 3], replicas: 1, fixed: 0.0 },
                GroupSpec { costs: vec![1.1; 3], replicas: 1, fixed: 0.0 },
                GroupSpec { costs: vec![1.2; 3], replicas: 1, fixed: 0.0 },
            ],
            demand: vec![30, 30, 30],
        };
        // tiny budget: still returns a feasible (heuristic-seeded) answer
        let a = solve_exact(&p, 10).unwrap();
        assert!(a.is_feasible(&p));
    }
}
