"""AOT export contract tests: HLO text artifacts + manifest consistency.

Validates the interchange the Rust runtime depends on without paying the
full lowering cost more than once (module-scoped export of the nano preset
to a temp dir).
"""

import hashlib
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def export_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts_nano")
    aot.export("nano", str(d), seed=0)
    return str(d)


@pytest.fixture(scope="module")
def manifest(export_dir):
    with open(os.path.join(export_dir, "manifest.json")) as f:
        return json.load(f)


class TestManifest:
    def test_offsets_contiguous_and_sized(self, manifest):
        for key, total_key in (("base_params", "base_param_count"),
                               ("lora_params", "lora_param_count")):
            off = 0
            for e in manifest[key]:
                assert e["offset"] == off, e["name"]
                numel = int(np.prod(e["shape"])) if e["shape"] else 1
                assert numel == e["size"], e["name"]
                off += e["size"]
            assert off == manifest[total_key]

    def test_artifact_files_exist_with_hash(self, export_dir, manifest):
        for a in manifest["artifacts"]:
            p = os.path.join(export_dir, a["file"])
            assert os.path.exists(p), a["file"]
            text = open(p).read()
            assert hashlib.sha256(text.encode()).hexdigest() == a["sha256"]

    def test_model_meta_matches_preset(self, manifest):
        cfg = M.PRESETS["nano"]
        assert manifest["model"]["vocab"] == cfg.vocab
        assert manifest["model"]["n_tasks"] == cfg.n_tasks
        assert manifest["model"]["block_rows"] == cfg.block_rows

    def test_shapes_cover_train_and_eval(self, manifest):
        kinds = {(a["kind"], a["batch"], a["seq"]) for a in manifest["artifacts"]}
        trains = [k for k in kinds if k[0] == "train"]
        evals = [k for k in kinds if k[0] == "eval"]
        assert len(trains) == len(aot.SHAPES["nano"])
        assert len(evals) == 1


class TestHloText:
    def test_hlo_is_parseable_text(self, export_dir, manifest):
        a = manifest["artifacts"][0]
        text = open(os.path.join(export_dir, a["file"])).read()
        assert text.startswith("HloModule"), "not HLO text"
        # entry computation must mention the 4 parameters
        assert "parameter(0)" in text
        assert "parameter(3)" in text
        assert "parameter(4)" not in text

    def test_reexport_is_deterministic(self, export_dir, manifest, tmp_path):
        d2 = tmp_path / "again"
        aot.export("nano", str(d2), seed=0)
        with open(d2 / "manifest.json") as f:
            m2 = json.load(f)
        for a1, a2 in zip(manifest["artifacts"], m2["artifacts"]):
            assert a1["sha256"] == a2["sha256"], a1["file"]


class TestNumericsParity:
    def test_exported_fn_matches_direct_call(self):
        """The function we lower equals the function we run in tests."""
        cfg = M.PRESETS["nano"]
        built = M.build(cfg, seed=0)
        bsz, seqlen = aot.SHAPES["nano"][0]
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(
            rng.integers(1, cfg.vocab, size=(bsz, seqlen)), jnp.int32)
        segs = jnp.asarray(np.sort(rng.integers(0, cfg.n_tasks, bsz)), jnp.int32)
        loss, grad, toks, tl, tt = built["train_step"](
            built["base_flat"], built["lora_flat"], tokens, segs)
        assert np.isfinite(float(loss))
        assert float(toks) > 0
        assert grad.shape == built["lora_flat"].shape
        assert np.isfinite(np.asarray(grad)).all()
        # per-task sums consistent
        np.testing.assert_allclose(float(tt.sum()), float(toks), rtol=1e-6)
