"""L2 correctness: transformer + multi-LoRA model semantics.

Checks shape contracts, Pallas-vs-jnp path equivalence, gradient locality
(only the tasks present in the batch receive adapter gradients; the frozen
base gets none), and that a few SGD-on-Adam-ish steps actually reduce loss
on a memorizable batch.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.PRESETS["nano"]


@pytest.fixture(scope="module")
def built():
    return M.build(CFG, seed=0)


def _batch(key, cfg, bsz=4, seqlen=64, tasks=None):
    kt, ks = jax.random.split(key)
    tokens = jax.random.randint(kt, (bsz, seqlen), 1, cfg.vocab, jnp.int32)
    # pad tail of each sequence with PAD to exercise masking
    lengths = jax.random.randint(ks, (bsz,), seqlen // 2, seqlen + 1)
    mask = jnp.arange(seqlen)[None, :] < lengths[:, None]
    tokens = jnp.where(mask, tokens, M.PAD_ID)
    if tasks is None:
        seg = np.sort(np.random.default_rng(0).integers(0, cfg.n_tasks, bsz))
    else:
        seg = np.sort(np.asarray(tasks))
    return tokens, jnp.asarray(seg, jnp.int32)


class TestForward:
    def test_logits_shape(self, built):
        tokens, seg = _batch(jax.random.PRNGKey(0), CFG)
        logits = M.forward(CFG, built["base"], built["lora"], tokens, seg)
        assert logits.shape == (4, 64, CFG.vocab)
        assert bool(jnp.isfinite(logits).all())

    def test_pallas_matches_jnp_path(self, built):
        tokens, seg = _batch(jax.random.PRNGKey(1), CFG)
        # Adapters must be nonzero for the check to be meaningful.
        lora = jax.tree.map(
            lambda x: x + 0.01 * jax.random.normal(jax.random.PRNGKey(2), x.shape),
            built["lora"],
        )
        cfg_jnp = dataclasses.replace(CFG, use_pallas=False)
        l1 = M.forward(CFG, built["base"], lora, tokens, seg)
        l2 = M.forward(cfg_jnp, built["base"], lora, tokens, seg)
        np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)

    def test_zero_lora_task_independent(self, built):
        """With A=0 adapters, logits must not depend on task assignment."""
        tokens, _ = _batch(jax.random.PRNGKey(3), CFG)
        seg_a = jnp.zeros((4,), jnp.int32)
        seg_b = jnp.array([0, 1, 2, 2], jnp.int32)
        la = M.forward(CFG, built["base"], built["lora"], tokens, seg_a)
        lb = M.forward(CFG, built["base"], built["lora"], tokens, seg_b)
        np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-5)

    def test_rejects_unaligned_seqlen(self, built):
        tokens = jnp.ones((2, CFG.block_rows + 1), jnp.int32)
        seg = jnp.zeros((2,), jnp.int32)
        with pytest.raises(ValueError):
            M.forward(CFG, built["base"], built["lora"], tokens, seg)

    def test_causality(self, built):
        """Future-token perturbation must not change past logits."""
        tokens, seg = _batch(jax.random.PRNGKey(4), CFG, bsz=2)
        logits = M.forward(CFG, built["base"], built["lora"], tokens, seg)
        tokens2 = tokens.at[:, -1].set((tokens[:, -1] % (CFG.vocab - 1)) + 1)
        logits2 = M.forward(CFG, built["base"], built["lora"], tokens2, seg)
        np.testing.assert_allclose(logits[:, :-1], logits2[:, :-1],
                                   rtol=1e-5, atol=1e-5)


class TestLoss:
    def test_loss_finite_positive(self, built):
        tokens, seg = _batch(jax.random.PRNGKey(5), CFG)
        loss, (toks, task_loss, task_toks) = M.loss_fn(
            CFG, built["base"], built["lora"], tokens, seg)
        assert bool(jnp.isfinite(loss)) and float(loss) > 0
        assert float(toks) == float(task_toks.sum())
        np.testing.assert_allclose(float(task_loss.sum()),
                                   float(loss) * float(toks), rtol=1e-4)

    def test_task_loss_placement(self, built):
        tokens, seg = _batch(jax.random.PRNGKey(6), CFG, tasks=[1, 1, 2, 2])
        _, (_, task_loss, task_toks) = M.loss_fn(
            CFG, built["base"], built["lora"], tokens, seg)
        for t in range(CFG.n_tasks):
            if t not in (1, 2):
                assert float(task_loss[t]) == 0.0
                assert float(task_toks[t]) == 0.0

    def test_all_pad_targets_no_nan(self, built):
        tokens = jnp.full((2, 64), M.PAD_ID, jnp.int32).at[:, 0].set(5)
        seg = jnp.zeros((2,), jnp.int32)
        loss, _ = M.loss_fn(CFG, built["base"], built["lora"], tokens, seg)
        assert bool(jnp.isfinite(loss))


class TestTrainStep:
    def test_grad_locality(self, built):
        """Only the adapters of tasks present in the batch get gradients."""
        tokens, seg = _batch(jax.random.PRNGKey(7), CFG, tasks=[0, 0, 2, 2])
        loss, gflat, *_ = built["train_step"](
            built["base_flat"], built["lora_flat"], tokens, seg)
        g = built["lora_unravel"](gflat)
        for layer in g["layers"]:
            for name in ("b_qkv", "a_qkv", "b_up", "a_up"):
                arr = layer[name]
                assert float(jnp.abs(arr[1]).max()) == 0.0, "absent task got grads"
        # present tasks must receive nonzero gradient somewhere
        total = sum(float(jnp.abs(l["a_qkv"][0]).sum()) +
                    float(jnp.abs(l["a_qkv"][2]).sum()) for l in g["layers"])
        assert total > 0

    def test_grad_flat_size(self, built):
        tokens, seg = _batch(jax.random.PRNGKey(8), CFG)
        _, gflat, *_ = built["train_step"](
            built["base_flat"], built["lora_flat"], tokens, seg)
        assert gflat.shape == built["lora_flat"].shape

    def test_loss_decreases_with_adam(self, built):
        """A few Adam steps on one fixed batch must reduce the loss."""
        tokens, seg = _batch(jax.random.PRNGKey(9), CFG, bsz=2, tasks=[0, 1])
        step = jax.jit(built["train_step"])
        lflat = built["lora_flat"]
        m = jnp.zeros_like(lflat)
        v = jnp.zeros_like(lflat)
        lr, b1, b2, eps = 1e-2, 0.9, 0.999, 1e-8
        losses = []
        for i in range(1, 9):
            loss, g, *_ = step(built["base_flat"], lflat, tokens, seg)
            losses.append(float(loss))
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / (1 - b1 ** i)
            vhat = v / (1 - b2 ** i)
            lflat = lflat - lr * mhat / (jnp.sqrt(vhat) + eps)
        assert losses[-1] < losses[0] * 0.95, f"no learning: {losses}"


class TestManifest:
    def test_offsets_contiguous(self, built):
        for table, flat in (
            (built["base_manifest"], built["base_flat"]),
            (built["lora_manifest"], built["lora_flat"]),
        ):
            off = 0
            for e in table:
                assert e["offset"] == off
                assert e["size"] == int(np.prod(e["shape"])) if e["shape"] else 1
                off += e["size"]
            assert off == flat.size

    def test_flatten_order_matches_manifest(self, built):
        """Writing init values per the manifest reproduces ravel_pytree order."""
        base = built["base"]
        flat = built["base_flat"]
        leaves = jax.tree_util.tree_leaves(base)
        sizes = [int(l.size) for l in leaves]
        assert sizes == [e["size"] for e in built["base_manifest"]]
        # spot-check: first leaf contents occupy the first slot
        np.testing.assert_allclose(
            np.asarray(flat[: sizes[0]]),
            np.asarray(leaves[0]).reshape(-1), rtol=1e-6)

    def test_lora_init_kinds(self, built):
        kinds = {e["name"]: e["init"]["kind"] for e in built["lora_manifest"]}
        for name, kind in kinds.items():
            if "['a_" in name:
                assert kind == "zeros", name
            else:
                assert kind == "normal", name
