"""L1 correctness: Pallas multi-LoRA kernels vs the pure-jnp oracle.

This is the CORE correctness signal for the compute hot-spot: forward,
dX (forward kernel with transposed operands), and dB/dA (adapter-grad
kernel with revisit accumulation) are all pinned against ref.py, including
a hypothesis sweep over shapes, task layouts, and dtypes.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.multi_lora import (
    adapter_grads_pallas,
    multi_lora_matmul,
    multi_lora_matmul_pallas,
)
from compile.kernels.ref import adapter_grads_ref, multi_lora_ref, row_task_ids

jax.config.update("jax_platform_name", "cpu")


def _mk(key, m, k, n, t, r, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (m, k), dtype)
    w = jax.random.normal(k2, (k, n), dtype) / np.sqrt(k)
    b = jax.random.normal(k3, (t, k, r), dtype) / np.sqrt(k)
    a = jax.random.normal(k4, (t, r, n), dtype) / np.sqrt(r)
    return x, w, b, a


def _sorted_tids(rng, nblocks, t):
    tids = np.sort(rng.integers(0, t, size=nblocks)).astype(np.int32)
    return jnp.asarray(tids)


TOL = dict(rtol=2e-4, atol=2e-4)


class TestForward:
    def test_basic(self):
        m, k, n, t, r, bm = 256, 64, 128, 4, 8, 64
        x, w, b, a = _mk(jax.random.PRNGKey(0), m, k, n, t, r)
        tids = jnp.array([0, 1, 1, 3], jnp.int32)
        out = multi_lora_matmul_pallas(x, w, b, a, tids, block_rows=bm, block_cols=64)
        ref = multi_lora_ref(x, w, b, a, tids, block_rows=bm)
        np.testing.assert_allclose(out, ref, **TOL)

    def test_single_task_equals_plain_lora(self):
        m, k, n, r, bm = 128, 32, 64, 4, 32
        x, w, b, a = _mk(jax.random.PRNGKey(1), m, k, n, 1, r)
        tids = jnp.zeros((m // bm,), jnp.int32)
        out = multi_lora_matmul_pallas(x, w, b, a, tids, block_rows=bm, block_cols=64)
        plain = x @ w + (x @ b[0]) @ a[0]
        np.testing.assert_allclose(out, plain, **TOL)

    def test_scaling(self):
        m, k, n, t, r, bm = 128, 32, 64, 3, 4, 64
        x, w, b, a = _mk(jax.random.PRNGKey(2), m, k, n, t, r)
        tids = jnp.array([0, 2], jnp.int32)
        for s in (0.0, 0.5, 2.0):
            out = multi_lora_matmul_pallas(x, w, b, a, tids, scaling=s,
                                           block_rows=bm, block_cols=64)
            ref = multi_lora_ref(x, w, b, a, tids, scaling=s, block_rows=bm)
            np.testing.assert_allclose(out, ref, **TOL)

    def test_zero_adapters_is_base_matmul(self):
        m, k, n, t, r, bm = 128, 32, 64, 2, 4, 64
        x, w, _, _ = _mk(jax.random.PRNGKey(3), m, k, n, t, r)
        b = jnp.zeros((t, k, r))
        a = jnp.zeros((t, r, n))
        tids = jnp.array([0, 1], jnp.int32)
        out = multi_lora_matmul_pallas(x, w, b, a, tids, block_rows=bm, block_cols=64)
        np.testing.assert_allclose(out, x @ w, **TOL)

    def test_column_tiling_invariance(self):
        m, k, n, t, r, bm = 128, 64, 256, 3, 8, 64
        x, w, b, a = _mk(jax.random.PRNGKey(4), m, k, n, t, r)
        tids = jnp.array([1, 2], jnp.int32)
        outs = [
            multi_lora_matmul_pallas(x, w, b, a, tids, block_rows=bm, block_cols=bc)
            for bc in (64, 128, 256)
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)

    def test_rejects_bad_shapes(self):
        x, w, b, a = _mk(jax.random.PRNGKey(5), 128, 32, 64, 2, 4)
        with pytest.raises(ValueError):
            multi_lora_matmul_pallas(x, w, b, a, jnp.zeros((2,), jnp.int32),
                                     block_rows=100, block_cols=64)
        with pytest.raises(ValueError):
            multi_lora_matmul_pallas(x, w, b, a, jnp.zeros((3,), jnp.int32),
                                     block_rows=64, block_cols=64)
        with pytest.raises(ValueError):
            multi_lora_matmul_pallas(x, w[:, :63], b, a, jnp.zeros((2,), jnp.int32),
                                     block_rows=64, block_cols=63)

    @settings(max_examples=20, deadline=None)
    @given(
        nblocks=st.integers(1, 6),
        k=st.sampled_from([16, 32, 64]),
        n=st.sampled_from([32, 64, 128]),
        t=st.integers(1, 5),
        r=st.sampled_from([1, 4, 8]),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, nblocks, k, n, t, r, seed):
        bm = 32
        m = nblocks * bm
        rng = np.random.default_rng(seed)
        x, w, b, a = _mk(jax.random.PRNGKey(seed), m, k, n, t, r)
        tids = _sorted_tids(rng, nblocks, t)
        out = multi_lora_matmul_pallas(x, w, b, a, tids, block_rows=bm, block_cols=n)
        ref = multi_lora_ref(x, w, b, a, tids, block_rows=bm)
        np.testing.assert_allclose(out, ref, **TOL)

    def test_bfloat16(self):
        m, k, n, t, r, bm = 128, 32, 64, 3, 4, 32
        x, w, b, a = _mk(jax.random.PRNGKey(7), m, k, n, t, r, jnp.bfloat16)
        tids = jnp.array([0, 0, 1, 2], jnp.int32)
        out = multi_lora_matmul_pallas(x, w, b, a, tids, block_rows=bm, block_cols=64)
        ref = multi_lora_ref(x, w, b, a, tids, block_rows=bm)
        np.testing.assert_allclose(out.astype(np.float32), ref.astype(np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestAdapterGrads:
    def test_basic(self):
        m, k, n, t, r, bm = 256, 32, 48, 4, 8, 64
        x, _, b, a = _mk(jax.random.PRNGKey(0), m, k, n, t, r)
        dy = jax.random.normal(jax.random.PRNGKey(9), (m, n))
        tids = jnp.array([0, 1, 1, 3], jnp.int32)
        db, da = adapter_grads_pallas(x, dy, b, a, tids, block_rows=bm)
        dbr, dar = adapter_grads_ref(x, dy, b, a, tids, block_rows=bm)
        np.testing.assert_allclose(db, dbr, **TOL)
        np.testing.assert_allclose(da, dar, **TOL)

    def test_unvisited_tasks_zero(self):
        m, k, n, t, r, bm = 128, 16, 32, 5, 4, 64
        x, _, b, a = _mk(jax.random.PRNGKey(1), m, k, n, t, r)
        dy = jax.random.normal(jax.random.PRNGKey(2), (m, n))
        tids = jnp.array([1, 1], jnp.int32)  # only task 1 visited
        db, da = adapter_grads_pallas(x, dy, b, a, tids, block_rows=bm)
        for tt in (0, 2, 3, 4):
            assert float(jnp.abs(db[tt]).max()) == 0.0
            assert float(jnp.abs(da[tt]).max()) == 0.0

    @settings(max_examples=15, deadline=None)
    @given(
        nblocks=st.integers(1, 5),
        t=st.integers(1, 4),
        seed=st.integers(0, 2**16),
    )
    def test_hypothesis_sweep(self, nblocks, t, seed):
        bm, k, n, r = 32, 16, 32, 4
        m = nblocks * bm
        rng = np.random.default_rng(seed)
        x, _, b, a = _mk(jax.random.PRNGKey(seed), m, k, n, t, r)
        dy = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, n))
        tids = _sorted_tids(rng, nblocks, t)
        db, da = adapter_grads_pallas(x, dy, b, a, tids, block_rows=bm)
        dbr, dar = adapter_grads_ref(x, dy, b, a, tids, block_rows=bm)
        np.testing.assert_allclose(db, dbr, **TOL)
        np.testing.assert_allclose(da, dar, **TOL)


class TestCustomVJP:
    """End-to-end autodiff through the fused op vs autodiff through ref."""

    def _setup(self, seed=0, m=128, k=32, n=64, t=3, r=4, bm=32):
        x, w, b, a = _mk(jax.random.PRNGKey(seed), m, k, n, t, r)
        rng = np.random.default_rng(seed)
        tids = _sorted_tids(rng, m // bm, t)
        return x, w, b, a, tids, bm

    def test_grads_match_ref(self):
        x, w, b, a, tids, bm = self._setup()

        def loss_pallas(x, b, a):
            y = multi_lora_matmul(x, w, b, a, tids, 1.25, bm, 64)
            return jnp.sum(jnp.sin(y))

        def loss_ref(x, b, a):
            y = multi_lora_ref(x, w, b, a, tids, scaling=1.25, block_rows=bm)
            return jnp.sum(jnp.sin(y))

        gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(x, b, a)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, b, a)
        for p, r_ in zip(gp, gr):
            np.testing.assert_allclose(p, r_, rtol=5e-4, atol=5e-4)

    def test_w_grad_matches_dense(self):
        x, w, b, a, tids, bm = self._setup(seed=3)

        def loss_pallas(w):
            return jnp.sum(multi_lora_matmul(x, w, b, a, tids, 1.0, bm, 64) ** 2)

        def loss_ref(w):
            return jnp.sum(multi_lora_ref(x, w, b, a, tids, block_rows=bm) ** 2)

        np.testing.assert_allclose(jax.grad(loss_pallas)(w), jax.grad(loss_ref)(w),
                                   rtol=5e-4, atol=5e-4)

    def test_value_matches_forward(self):
        x, w, b, a, tids, bm = self._setup(seed=5)
        y1 = multi_lora_matmul(x, w, b, a, tids, 1.0, bm, 64)
        y2 = multi_lora_matmul_pallas(x, w, b, a, tids, block_rows=bm, block_cols=64)
        np.testing.assert_allclose(y1, y2, rtol=0, atol=0)

    def test_jittable(self):
        x, w, b, a, tids, bm = self._setup(seed=6)
        f = jax.jit(functools.partial(multi_lora_matmul,
                                      scaling=1.0, block_rows=bm, block_cols=64))
        y = f(x, w, b, a, tids)
        ref = multi_lora_ref(x, w, b, a, tids, block_rows=bm)
        np.testing.assert_allclose(y, ref, **TOL)
