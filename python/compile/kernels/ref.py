"""Pure-jnp oracle for the fused multi-LoRA matmul.

This is the correctness ground truth the Pallas kernels (multi_lora.py) are
pinned against by pytest/hypothesis. It is deliberately written with dense
gathers and einsums -- slow but obviously correct.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["multi_lora_ref", "adapter_grads_ref", "row_task_ids"]


def row_task_ids(block_task_ids: jax.Array, block_rows: int) -> jax.Array:
    """Expand per-block task ids back to per-row ids."""
    return jnp.repeat(block_task_ids, block_rows)


def multi_lora_ref(
    x: jax.Array,
    w: jax.Array,
    b_stack: jax.Array,
    a_stack: jax.Array,
    block_task_ids: jax.Array,
    *,
    scaling: float = 1.0,
    block_rows: int = 128,
) -> jax.Array:
    """Y[m] = X[m] W + scaling * (X[m] B_t) A_t with t = task(row m)."""
    rt = row_task_ids(block_task_ids, block_rows)
    base = jnp.dot(x, w)
    xb = jnp.einsum("mk,mkr->mr", x, b_stack[rt])
    lora = jnp.einsum("mr,mrn->mn", xb, a_stack[rt])
    return (base + scaling * lora).astype(x.dtype)


def adapter_grads_ref(
    x: jax.Array,
    dy: jax.Array,
    b_stack: jax.Array,
    a_stack: jax.Array,
    block_task_ids: jax.Array,
    *,
    scaling: float = 1.0,
    block_rows: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Reference (dB_stack, dA_stack) via segment scatter-add."""
    rt = row_task_ids(block_task_ids, block_rows)
    xf = x.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    # dB_t = s * sum_{m in t} x_m (dy_m A_t^T)
    dxa = jnp.einsum("mn,mrn->mr", dyf, a_stack[rt].astype(jnp.float32))
    db = jnp.zeros(b_stack.shape, jnp.float32).at[rt].add(
        scaling * jnp.einsum("mk,mr->mkr", xf, dxa)
    )
    # dA_t = s * sum_{m in t} (x_m B_t)^T dy_m
    xb = jnp.einsum("mk,mkr->mr", xf, b_stack[rt].astype(jnp.float32))
    da = jnp.zeros(a_stack.shape, jnp.float32).at[rt].add(
        scaling * jnp.einsum("mr,mn->mrn", xb, dyf)
    )
    return db.astype(b_stack.dtype), da.astype(a_stack.dtype)
