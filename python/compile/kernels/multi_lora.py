"""L1: fused multi-LoRA matmul Pallas kernels.

The compute hot-spot of joint LoRA fine-tuning (LobRA, PVLDB'25): a fused
batch holds rows (tokens) belonging to *different* FT tasks, and every row
must go through the shared base weight ``W`` plus its *own* task's low-rank
adapter ``(B_t, A_t)``:

    Y[m] = X[m] @ W + scaling * (X[m] @ B_t) @ A_t,   t = task(m)

GPU systems (Punica/SLoRA) implement this with an SGMV CUDA kernel that
gathers adapters at warp granularity.  Re-thought for TPU (see
DESIGN.md#hardware-adaptation): rows are sorted by task and tiled into
``block_rows`` VMEM blocks, one task per block; a scalar-prefetch array
gives the task id of each row block, and the BlockSpec index map streams
the right adapter slice HBM->VMEM while the MXU runs the dense base matmul.
The coordinator (L3) guarantees the sorted, block-aligned layout because it
already buckets and batches sequences per task.

Three kernels live here:

* ``_fused_fwd_kernel``   -- Y = X @ W + s * (X @ B_t) @ A_t
* the same kernel, called with transposed operands, computes
  dX = dY @ W^T + s * (dY @ A_t^T) @ B_t^T
* ``_adapter_grad_kernel`` -- per-task dB_t / dA_t with revisit
  accumulation (consecutive row blocks of one task accumulate into the
  same output block).

All kernels run under ``interpret=True`` so they lower to plain HLO that
the CPU PJRT plugin can execute; on a real TPU the same BlockSpecs compile
through Mosaic.  Correctness is pinned against ``ref.py`` by pytest.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "multi_lora_matmul",
    "multi_lora_matmul_pallas",
    "adapter_grads_pallas",
    "block_task_ids_from_rows",
]

# Set False to compile for a real TPU (Mosaic); CPU PJRT requires True.
INTERPRET = True


def block_task_ids_from_rows(row_task_ids: jax.Array, block_rows: int) -> jax.Array:
    """Collapse per-row task ids (sorted, block-aligned) to per-block ids."""
    return row_task_ids[::block_rows]


# ---------------------------------------------------------------------------
# Forward kernel: one (row-block, col-block) tile per grid step.
# ---------------------------------------------------------------------------


def _fused_fwd_kernel(tids, x_ref, w_ref, b_ref, a_ref, o_ref, *, scaling: float):
    del tids  # only consumed by the BlockSpec index maps
    x = x_ref[...]
    base = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    xb = jnp.dot(x, b_ref[0], preferred_element_type=jnp.float32)
    lora = jnp.dot(xb, a_ref[0], preferred_element_type=jnp.float32)
    o_ref[...] = (base + scaling * lora).astype(o_ref.dtype)


def multi_lora_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    b_stack: jax.Array,
    a_stack: jax.Array,
    block_task_ids: jax.Array,
    *,
    scaling: float = 1.0,
    block_rows: int = 128,
    block_cols: int = 128,
) -> jax.Array:
    """Fused multi-adapter LoRA matmul (Pallas, forward only).

    Args:
      x: ``[M, K]`` activations, rows sorted by task, ``M % block_rows == 0``.
      w: ``[K, N]`` shared (frozen) base weight.
      b_stack: ``[T, K, r]`` per-task down-projections.
      a_stack: ``[T, r, N]`` per-task up-projections.
      block_task_ids: ``[M // block_rows]`` int32, non-decreasing.
      scaling: LoRA scaling alpha/r.
      block_rows / block_cols: VMEM tile sizes.

    Returns:
      ``[M, N]`` fused output.
    """
    m, k = x.shape
    k2, n = w.shape
    t, k3, r = b_stack.shape
    t2, r2, n2 = a_stack.shape
    if k != k2 or k != k3 or n != n2 or r != r2 or t != t2:
        raise ValueError(
            f"inconsistent shapes x={x.shape} w={w.shape} "
            f"b={b_stack.shape} a={a_stack.shape}"
        )
    if m % block_rows != 0:
        raise ValueError(f"M={m} not a multiple of block_rows={block_rows}")
    if n % block_cols != 0:
        raise ValueError(f"N={n} not a multiple of block_cols={block_cols}")
    if block_task_ids.shape != (m // block_rows,):
        raise ValueError(
            f"block_task_ids shape {block_task_ids.shape} != ({m // block_rows},)"
        )

    grid = (m // block_rows, n // block_cols)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i, j, tids: (i, 0)),
            pl.BlockSpec((k, block_cols), lambda i, j, tids: (0, j)),
            pl.BlockSpec((1, k, r), lambda i, j, tids: (tids[i], 0, 0)),
            pl.BlockSpec((1, r, block_cols), lambda i, j, tids: (tids[i], 0, j)),
        ],
        out_specs=pl.BlockSpec((block_rows, block_cols), lambda i, j, tids: (i, j)),
    )
    kernel = functools.partial(_fused_fwd_kernel, scaling=float(scaling))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(block_task_ids.astype(jnp.int32), x, w, b_stack, a_stack)


# ---------------------------------------------------------------------------
# Adapter-gradient kernel: grid over row blocks, revisit accumulation into
# the per-task output block selected by the scalar-prefetched task id.
# ---------------------------------------------------------------------------


def _adapter_grad_kernel(tids, x_ref, dy_ref, b_ref, a_ref, db_ref, da_ref, *, scaling: float):
    i = pl.program_id(0)
    t = tids[i]
    # First visit of this task's output block: rows are sorted by task, so
    # a new task starts exactly when the id changes (or at i == 0).
    first = jnp.logical_or(i == 0, tids[jnp.maximum(i - 1, 0)] != t)

    @pl.when(first)
    def _init():
        db_ref[...] = jnp.zeros_like(db_ref)
        da_ref[...] = jnp.zeros_like(da_ref)

    x = x_ref[...].astype(jnp.float32)
    dy = dy_ref[...].astype(jnp.float32)
    # dB_t += s * X^T (dY A_t^T);  dA_t += s * (X B_t)^T dY
    dxa = jnp.dot(dy, a_ref[0].astype(jnp.float32).T, preferred_element_type=jnp.float32)
    db_ref[0] += scaling * jnp.dot(x.T, dxa, preferred_element_type=jnp.float32)
    xb = jnp.dot(x, b_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    da_ref[0] += scaling * jnp.dot(xb.T, dy, preferred_element_type=jnp.float32)


def adapter_grads_pallas(
    x: jax.Array,
    dy: jax.Array,
    b_stack: jax.Array,
    a_stack: jax.Array,
    block_task_ids: jax.Array,
    *,
    scaling: float = 1.0,
    block_rows: int = 128,
) -> Tuple[jax.Array, jax.Array]:
    """Per-task LoRA adapter gradients ``(dB_stack, dA_stack)``.

    Output blocks of tasks that receive no rows are masked to zero (Pallas
    leaves unvisited output blocks undefined).
    """
    m, k = x.shape
    m2, n = dy.shape
    t, _, r = b_stack.shape
    if m != m2:
        raise ValueError(f"x rows {m} != dy rows {m2}")
    if m % block_rows != 0:
        raise ValueError(f"M={m} not a multiple of block_rows={block_rows}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, k), lambda i, tids: (i, 0)),
            pl.BlockSpec((block_rows, n), lambda i, tids: (i, 0)),
            pl.BlockSpec((1, k, r), lambda i, tids: (tids[i], 0, 0)),
            pl.BlockSpec((1, r, n), lambda i, tids: (tids[i], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k, r), lambda i, tids: (tids[i], 0, 0)),
            pl.BlockSpec((1, r, n), lambda i, tids: (tids[i], 0, 0)),
        ],
    )
    kernel = functools.partial(_adapter_grad_kernel, scaling=float(scaling))
    db, da = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(b_stack.shape, jnp.float32),
            jax.ShapeDtypeStruct(a_stack.shape, jnp.float32),
        ],
        interpret=INTERPRET,
    )(block_task_ids.astype(jnp.int32), x, dy, b_stack, a_stack)

    visited = jnp.zeros((t,), dtype=bool).at[block_task_ids].set(True)
    db = jnp.where(visited[:, None, None], db, 0.0).astype(b_stack.dtype)
    da = jnp.where(visited[:, None, None], da, 0.0).astype(a_stack.dtype)
    return db, da


# ---------------------------------------------------------------------------
# Differentiable fused op (custom VJP). The backward pass reuses the forward
# kernel with transposed operands for dX and the adapter-grad kernel for
# dB/dA. dW is computed densely with jnp; when the base weight is frozen
# (the LoRA setting) the XLA DCE pass removes it from the lowered module.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def multi_lora_matmul(
    x: jax.Array,
    w: jax.Array,
    b_stack: jax.Array,
    a_stack: jax.Array,
    block_task_ids: jax.Array,
    scaling: float = 1.0,
    block_rows: int = 128,
    block_cols: int = 128,
) -> jax.Array:
    """Differentiable fused multi-LoRA matmul. See ``multi_lora_matmul_pallas``."""
    return multi_lora_matmul_pallas(
        x, w, b_stack, a_stack, block_task_ids,
        scaling=scaling, block_rows=block_rows, block_cols=block_cols,
    )


def _fwd(x, w, b_stack, a_stack, block_task_ids, scaling, block_rows, block_cols):
    y = multi_lora_matmul_pallas(
        x, w, b_stack, a_stack, block_task_ids,
        scaling=scaling, block_rows=block_rows, block_cols=block_cols,
    )
    return y, (x, w, b_stack, a_stack, block_task_ids)


def _bwd(scaling, block_rows, block_cols, res, dy):
    x, w, b_stack, a_stack, block_task_ids = res
    k = x.shape[1]
    # dX = dY W^T + s (dY A_t^T) B_t^T -- the same segmented structure with
    # (W^T, A^T as the down-proj, B^T as the up-proj).
    dcols = min(block_cols, k) if k % min(block_cols, k) == 0 else k
    # Tile the K output dimension only if it divides evenly; else one tile.
    dcols = block_cols if k % block_cols == 0 else k
    dx = multi_lora_matmul_pallas(
        dy,
        jnp.swapaxes(w, 0, 1),
        jnp.swapaxes(a_stack, 1, 2),
        jnp.swapaxes(b_stack, 1, 2),
        block_task_ids,
        scaling=scaling,
        block_rows=block_rows,
        block_cols=dcols,
    ).astype(x.dtype)
    db, da = adapter_grads_pallas(
        x, dy, b_stack, a_stack, block_task_ids,
        scaling=scaling, block_rows=block_rows,
    )
    # Dense base-weight grad; DCE-eliminated when W is frozen.
    dw = jnp.dot(x.T, dy).astype(w.dtype)
    dtids = jnp.zeros(block_task_ids.shape, dtype=jax.dtypes.float0)
    return dx, dw, db, da, dtids


multi_lora_matmul.defvjp(_fwd, _bwd)
