"""L2: transformer LM with fused multi-task LoRA adapters (JAX, build-time).

This is the compute graph the Rust coordinator executes on every replica:
a pre-LN causal transformer whose projection layers (QKV, output, MLP
up/down) each carry a *stack* of per-task LoRA adapters applied through the
L1 Pallas kernel (`kernels.multi_lora`). A fused microbatch mixes sequences
from several FT tasks; `seg_ids[b]` names the task of each sequence and the
kernel routes every row through its own adapter while the frozen base
weights run as one dense MXU matmul.

Exported entry points (lowered to HLO text by `aot.py`):

* ``train_step``  -- loss + flat LoRA gradient for one microbatch. The
  optimizer (Adam) lives in Rust (L3): gradients are returned as a single
  flat f32 vector so the coordinator can accumulate across microbatches
  and replicas without knowing the pytree structure.
* ``eval_loss``   -- forward-only loss (validation).

Both take the *flat* base parameter vector and *flat* LoRA vector; the
unravel closures are baked into the jitted function at lowering time, and
``param_manifest`` tells Rust how to initialize / checkpoint the vectors.

Python never runs at training time: this module exists only under
``make artifacts``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels.multi_lora import multi_lora_matmul
from .kernels.ref import multi_lora_ref

PAD_ID = 0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer + multi-LoRA hyperparameters."""

    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_ff: int = 1024
    n_tasks: int = 4
    lora_rank: int = 8
    lora_alpha: float = 16.0
    block_rows: int = 64  # Pallas row-tile; sequence lengths must be multiples
    block_cols: int = 128
    use_pallas: bool = True
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def lora_scaling(self) -> float:
        return self.lora_alpha / self.lora_rank

    def validate(self) -> None:
        if self.d_model % self.n_heads != 0:
            raise ValueError("d_model must be divisible by n_heads")
        if self.d_model % self.block_cols != 0 and self.block_cols % self.d_model != 0:
            raise ValueError("block_cols must tile d_model")


# Named presets used by aot.py / the Rust config system. "tiny" is the CI /
# e2e-on-CPU scale; "base100m" is the ~100M-parameter configuration.
PRESETS: Dict[str, ModelConfig] = {
    "nano": ModelConfig(vocab=512, d_model=128, n_layers=2, n_heads=4, d_ff=256,
                        n_tasks=3, lora_rank=4, block_rows=32, block_cols=128),
    "tiny": ModelConfig(vocab=2048, d_model=256, n_layers=4, n_heads=8, d_ff=1024,
                        n_tasks=6, lora_rank=8, block_rows=64, block_cols=128),
    "small": ModelConfig(vocab=4096, d_model=384, n_layers=6, n_heads=8, d_ff=1536,
                         n_tasks=6, lora_rank=8, block_rows=64, block_cols=128),
    "base100m": ModelConfig(vocab=8192, d_model=768, n_layers=12, n_heads=12,
                            d_ff=3072, n_tasks=12, lora_rank=8,
                            block_rows=128, block_cols=128),
}

# Projection layers that carry LoRA adapters, with (in, out) dims.
_LORA_PROJS = ("qkv", "out", "up", "down")


def _proj_dims(cfg: ModelConfig, name: str) -> Tuple[int, int]:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "qkv": (d, 3 * d),
        "out": (d, d),
        "up": (d, f),
        "down": (f, d),
    }[name]


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def init_base_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    """Frozen base-model parameters (would be the pre-trained checkpoint)."""
    keys = iter(jax.random.split(key, 4 + 8 * cfg.n_layers))
    d = cfg.d_model

    def dense(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in))

    params: Dict[str, Any] = {
        "embed": jax.random.normal(next(keys), (cfg.vocab, d), jnp.float32) * 0.02,
        "ln_f": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
            "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        }
        for name in _LORA_PROJS:
            fin, fout = _proj_dims(cfg, name)
            layer[f"w_{name}"] = dense(next(keys), (fin, fout), fin)
        params["layers"].append(layer)
    return params


def init_lora_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    """Per-task LoRA stacks. B ~ N(0, 1/in), A = 0 => delta starts at zero."""
    keys = iter(jax.random.split(key, 2 * len(_LORA_PROJS) * cfg.n_layers))
    t, r = cfg.n_tasks, cfg.lora_rank
    lora: Dict[str, Any] = {"layers": []}
    for _ in range(cfg.n_layers):
        layer = {}
        for name in _LORA_PROJS:
            fin, fout = _proj_dims(cfg, name)
            layer[f"b_{name}"] = (
                jax.random.normal(next(keys), (t, fin, r), jnp.float32)
                / jnp.sqrt(fin)
            )
            layer[f"a_{name}"] = jnp.zeros((t, r, fout), jnp.float32)
            _ = next(keys)
        lora["layers"].append(layer)
    return lora


def flatten_params(params: Any) -> Tuple[jax.Array, Any]:
    """Flat f32 vector + unravel closure (the Rust-side representation)."""
    flat, unravel = ravel_pytree(params)
    return flat.astype(jnp.float32), unravel


def param_manifest(params: Any) -> List[Dict[str, Any]]:
    """Name/shape/offset/init table for the Rust initializer & checkpoints.

    Order matches ``ravel_pytree`` flattening order (tree_flatten order).
    """
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    table = []
    offset = 0
    for path, leaf in leaves_with_paths:
        name = jax.tree_util.keystr(path)
        size = int(leaf.size)
        entry = {
            "name": name,
            "shape": list(leaf.shape),
            "offset": offset,
            "size": size,
        }
        # Init rule, consumed by rust/src/train/init.rs.
        if name.endswith("['g']") and leaf.ndim == 1:
            entry["init"] = {"kind": "ones"}
        elif name.endswith("['b']") and leaf.ndim == 1:
            entry["init"] = {"kind": "zeros"}
        elif name.endswith("['embed']"):
            entry["init"] = {"kind": "normal", "std": 0.02}
        elif "['a_" in name:
            entry["init"] = {"kind": "zeros"}
        elif leaf.ndim >= 2:
            fan_in = int(leaf.shape[-2])
            entry["init"] = {"kind": "normal", "std": float(1.0 / (fan_in ** 0.5))}
        else:
            entry["init"] = {"kind": "zeros"}
        table.append(entry)
        offset += size
    return table


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------


def _layer_norm(x: jax.Array, g: jax.Array, b: jax.Array) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _rope(x: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding over [B, H, S, Dh]."""
    b, h, s, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = jnp.arange(s, dtype=jnp.float32)[:, None] * freqs[None, :]  # [S, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _lora_proj(
    cfg: ModelConfig,
    x2d: jax.Array,
    w: jax.Array,
    lora_layer: Dict[str, jax.Array],
    name: str,
    block_tids: jax.Array,
) -> jax.Array:
    """Apply one LoRA-augmented projection over flattened rows [M, in]."""
    b_stack = lora_layer[f"b_{name}"]
    a_stack = lora_layer[f"a_{name}"]
    w = jax.lax.stop_gradient(w)  # base model is frozen
    if cfg.use_pallas:
        n = w.shape[1]
        bc = cfg.block_cols if n % cfg.block_cols == 0 else n
        return multi_lora_matmul(
            x2d, w, b_stack, a_stack, block_tids,
            cfg.lora_scaling, cfg.block_rows, bc,
        )
    return multi_lora_ref(
        x2d, w, b_stack, a_stack, block_tids,
        scaling=cfg.lora_scaling, block_rows=cfg.block_rows,
    )


def forward(
    cfg: ModelConfig,
    base: Dict[str, Any],
    lora: Dict[str, Any],
    tokens: jax.Array,  # [B, S] int32
    seg_ids: jax.Array,  # [B] int32, non-decreasing task ids
) -> jax.Array:
    """Next-token logits [B, S, V]."""
    bsz, seqlen = tokens.shape
    d = cfg.d_model
    if seqlen % cfg.block_rows != 0:
        raise ValueError(f"seqlen {seqlen} must be a multiple of block_rows {cfg.block_rows}")
    # Rows of the flattened [B*S, d] activations are per-sequence contiguous,
    # so per-block task ids repeat each sequence's id S/block_rows times.
    block_tids = jnp.repeat(seg_ids.astype(jnp.int32), seqlen // cfg.block_rows)

    h = jax.lax.stop_gradient(base["embed"])[tokens]  # [B, S, d]

    causal = jnp.tril(jnp.ones((seqlen, seqlen), bool))
    pad_ok = tokens != PAD_ID  # [B, S] keys that are real tokens
    attn_mask = causal[None, None, :, :] & pad_ok[:, None, None, :]

    for li in range(cfg.n_layers):
        blayer, llayer = base["layers"][li], lora["layers"][li]
        # --- attention ---
        xn = _layer_norm(h, blayer["ln1"]["g"], blayer["ln1"]["b"])
        qkv = _lora_proj(cfg, xn.reshape(bsz * seqlen, d), blayer["w_qkv"],
                         llayer, "qkv", block_tids)
        qkv = qkv.reshape(bsz, seqlen, 3, cfg.n_heads, cfg.head_dim)
        q, k, v = (jnp.moveaxis(qkv[:, :, i], 1, 2) for i in range(3))  # [B,H,S,Dh]
        q = _rope(q, cfg.rope_theta)
        k = _rope(k, cfg.rope_theta)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(cfg.head_dim)
        scores = jnp.where(attn_mask, scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
        ctx = jnp.moveaxis(ctx, 1, 2).reshape(bsz * seqlen, d)
        h = h + _lora_proj(cfg, ctx, blayer["w_out"], llayer, "out",
                           block_tids).reshape(bsz, seqlen, d)
        # --- MLP ---
        xn = _layer_norm(h, blayer["ln2"]["g"], blayer["ln2"]["b"])
        up = _lora_proj(cfg, xn.reshape(bsz * seqlen, d), blayer["w_up"],
                        llayer, "up", block_tids)
        act = jax.nn.gelu(up)
        down = _lora_proj(cfg, act, blayer["w_down"], llayer, "down", block_tids)
        h = h + down.reshape(bsz, seqlen, d)

    h = _layer_norm(h, base["ln_f"]["g"], base["ln_f"]["b"])
    logits = jnp.einsum("bsd,vd->bsv", h, jax.lax.stop_gradient(base["embed"]))
    return logits


def loss_fn(
    cfg: ModelConfig,
    base: Dict[str, Any],
    lora: Dict[str, Any],
    tokens: jax.Array,
    seg_ids: jax.Array,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    """Mean next-token CE over non-pad targets + per-task sums.

    Returns (mean_loss, (token_count, per_task_loss_sum[T], per_task_tokens[T])).
    """
    logits = forward(cfg, base, lora, tokens, seg_ids)
    targets = tokens[:, 1:]
    logits = logits[:, :-1]
    mask = (targets != PAD_ID).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0] * mask
    per_seq_loss = nll.sum(axis=1)  # [B]
    per_seq_toks = mask.sum(axis=1)
    task_loss = jnp.zeros((cfg.n_tasks,), jnp.float32).at[seg_ids].add(per_seq_loss)
    task_toks = jnp.zeros((cfg.n_tasks,), jnp.float32).at[seg_ids].add(per_seq_toks)
    total_toks = per_seq_toks.sum()
    mean_loss = per_seq_loss.sum() / jnp.maximum(total_toks, 1.0)
    return mean_loss, (total_toks, task_loss, task_toks)


# ---------------------------------------------------------------------------
# Exported entry points (flat-vector interface for the Rust runtime)
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, base_unravel, lora_unravel):
    """(base_flat, lora_flat, tokens, seg_ids) ->
    (loss, grad_flat, token_count, task_loss[T], task_tokens[T])."""

    def train_step(base_flat, lora_flat, tokens, seg_ids):
        base = base_unravel(base_flat)

        def scalar_loss(lflat):
            lora = lora_unravel(lflat)
            return loss_fn(cfg, base, lora, tokens, seg_ids)

        (loss, (toks, task_loss, task_toks)), grad_flat = jax.value_and_grad(
            scalar_loss, has_aux=True
        )(lora_flat)
        return loss, grad_flat, toks, task_loss, task_toks

    return train_step


def make_eval_loss(cfg: ModelConfig, base_unravel, lora_unravel):
    """(base_flat, lora_flat, tokens, seg_ids) ->
    (loss, token_count, task_loss[T], task_tokens[T])."""

    def eval_loss(base_flat, lora_flat, tokens, seg_ids):
        base = base_unravel(base_flat)
        lora = lora_unravel(lora_flat)
        loss, (toks, task_loss, task_toks) = loss_fn(cfg, base, lora, tokens, seg_ids)
        return loss, toks, task_loss, task_toks

    return eval_loss


def build(cfg: ModelConfig, seed: int = 0):
    """Construct params + entry points. Returns a dict used by aot.py/tests."""
    cfg.validate()
    kb, kl = jax.random.split(jax.random.PRNGKey(seed))
    base = init_base_params(cfg, kb)
    lora = init_lora_params(cfg, kl)
    base_flat, base_unravel = flatten_params(base)
    lora_flat, lora_unravel = flatten_params(lora)
    return {
        "cfg": cfg,
        "base": base,
        "lora": lora,
        "base_flat": base_flat,
        "lora_flat": lora_flat,
        "base_unravel": base_unravel,
        "lora_unravel": lora_unravel,
        "train_step": make_train_step(cfg, base_unravel, lora_unravel),
        "eval_loss": make_eval_loss(cfg, base_unravel, lora_unravel),
        "base_manifest": param_manifest(base),
        "lora_manifest": param_manifest(lora),
    }
