"""AOT export: lower the L2 train/eval steps to HLO *text* artifacts.

Run once by ``make artifacts``; the Rust runtime (rust/src/runtime/) loads
the text with ``HloModuleProto::from_text_file``, compiles it on the PJRT
CPU client, and executes it on the request path. Python never runs again.

HLO **text** -- not ``lowered.compile().serialize()`` and not a serialized
``HloModuleProto`` -- is the interchange format: jax >= 0.5 emits protos
with 64-bit instruction ids that xla_extension 0.5.1 (what the published
``xla`` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``). The text
parser reassigns ids and round-trips cleanly. Lowered with
``return_tuple=True``; the Rust side unwraps with ``to_tuple()``.

Per-shape artifacts: the coordinator pads every microbatch to one of the
bucket shapes below, so one compiled executable per (batch, seqlen) bucket
is loaded at startup -- the same "one executable per model variant" regime
a real TPU deployment would use.

Usage: python -m compile.aot --out-dir ../artifacts [--preset tiny]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# (batch, seqlen) microbatch shapes exported per preset. Shapes keep
# batch*seqlen (token budget) roughly constant, mirroring how the LobRA
# coordinator packs chunks up to a replica's token capacity.
SHAPES: Dict[str, List[Tuple[int, int]]] = {
    "nano": [(8, 32), (4, 64), (2, 128)],
    "tiny": [(16, 64), (8, 128), (4, 256), (2, 512)],
    "small": [(16, 64), (8, 128), (4, 256), (2, 512)],
    "base100m": [(8, 128), (4, 256), (2, 512), (1, 1024)],
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def export(preset: str, out_dir: str, seed: int = 0) -> Dict[str, Any]:
    cfg = M.PRESETS[preset]
    built = M.build(cfg, seed=seed)
    base_flat, lora_flat = built["base_flat"], built["lora_flat"]
    os.makedirs(out_dir, exist_ok=True)

    artifacts = []

    def lower_and_write(fn, name: str, bsz: int, seqlen: int, outputs: List[str]):
        args = (
            _spec(base_flat.shape, jnp.float32),
            _spec(lora_flat.shape, jnp.float32),
            _spec((bsz, seqlen), jnp.int32),
            _spec((bsz,), jnp.int32),
        )
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}_b{bsz}_s{seqlen}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        artifacts.append({
            "file": fname,
            "kind": name,
            "batch": bsz,
            "seq": seqlen,
            "inputs": ["base_flat:f32", "lora_flat:f32",
                       f"tokens:i32[{bsz},{seqlen}]", f"seg_ids:i32[{bsz}]"],
            "outputs": outputs,
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        })
        print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB)")

    train_outputs = ["loss:f32", "grad_flat:f32", "tokens:f32",
                     f"task_loss:f32[{cfg.n_tasks}]", f"task_tokens:f32[{cfg.n_tasks}]"]
    eval_outputs = ["loss:f32", "tokens:f32",
                    f"task_loss:f32[{cfg.n_tasks}]", f"task_tokens:f32[{cfg.n_tasks}]"]

    for bsz, seqlen in SHAPES[preset]:
        lower_and_write(built["train_step"], "train", bsz, seqlen, train_outputs)
    # One eval artifact at the largest shape is enough for validation loss.
    bsz, seqlen = SHAPES[preset][-1]
    lower_and_write(built["eval_loss"], "eval", bsz, seqlen, eval_outputs)

    manifest = {
        "preset": preset,
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "n_tasks": cfg.n_tasks,
            "lora_rank": cfg.lora_rank, "lora_alpha": cfg.lora_alpha,
            "block_rows": cfg.block_rows, "pad_id": M.PAD_ID,
        },
        "base_param_count": int(base_flat.size),
        "lora_param_count": int(lora_flat.size),
        "base_params": built["base_manifest"],
        "lora_params": built["lora_manifest"],
        "shapes": [{"batch": b, "seq": s} for b, s in SHAPES[preset]],
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote manifest.json (base={base_flat.size:,} lora={lora_flat.size:,} params)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", default="tiny", choices=sorted(M.PRESETS))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    print(f"exporting preset={args.preset} -> {args.out_dir}")
    export(args.preset, args.out_dir, seed=args.seed)


if __name__ == "__main__":
    main()
