//! Profiling-based cost-model calibration on the *real* executor — the
//! paper's Appendix D methodology run end-to-end: time the AOT train step
//! at every exported microbatch shape on the PJRT CPU client, fit
//! `t(b,s) = β₀ + β₁·b·s + β₂·b·s²`, and report fit quality + predictions
//! for unseen shapes. This closes the loop between the L3 planner's cost
//! model and the actual L1/L2 artifacts.
//!
//! ```bash
//! make artifacts && cargo run --release --example profile_calibrate
//! ```

use lobra::costmodel::calibrate::{fit, Observation};
use lobra::data::SyntheticCorpus;
use lobra::runtime::Engine;
use lobra::util::clock::Stopwatch;

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::load("artifacts")?;
    let (base, lora) = engine.init_params(0);
    engine.set_base(&base)?;
    let m = engine.manifest().clone();
    let n_tasks = m.model.n_tasks as usize;
    let mut corpus = SyntheticCorpus::new(m.model.vocab as u32, n_tasks, 1);

    println!("profiling {} train-step shapes (3 reps each, 1 warmup)...", engine.shapes().len());
    let mut obs = Vec::new();
    for (b, s) in engine.shapes() {
        let tasks: Vec<usize> = (0..b as usize).map(|i| i % n_tasks).collect();
        let (toks, segs) = corpus.fused_microbatch(&tasks, s as usize);
        engine.train_step((b, s), &lora, &toks, &segs)?; // warmup
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Stopwatch::start();
            engine.train_step((b, s), &lora, &toks, &segs)?;
            best = best.min(t0.elapsed_secs());
        }
        println!("  t({b:>2}, {s:>4}) = {best:.3}s   ({:.0} tokens/s)", (b * s) as f64 / best);
        obs.push(Observation { b, s, seconds: best });
    }

    let Some(f) = fit(&obs) else {
        anyhow::bail!("not enough shapes to fit (need ≥3)");
    };
    println!(
        "\nfitted: t(b,s) = {:.4} + {:.3e}·b·s + {:.3e}·b·s²",
        f.beta0, f.beta1, f.beta2
    );
    println!("relative RMS error over profiled shapes: {:.1}%", f.rms_rel_error(&obs) * 100.0);

    println!("\npredictions at profiled + unseen shapes:");
    for (b, s) in [(16u64, 64u64), (8, 128), (4, 256), (2, 512), (4, 512), (1, 1024)] {
        println!("  t({b:>2}, {s:>4}) ≈ {:.3}s", f.predict(b, s));
    }
    println!(
        "\nattention share at s=512 (β₂·s / (β₁ + β₂·s)): {:.1}%",
        100.0 * f.beta2 * 512.0 / (f.beta1 + f.beta2 * 512.0)
    );
    Ok(())
}
