//! End-to-end validation: real joint multi-LoRA fine-tuning through all
//! three layers — the Rust coordinator draws a Table-4-shaped fused batch,
//! dispatches it over the virtual cluster's replicas with the MINMAX
//! solve, and the PJRT executor runs the dispatched loads as AOT-compiled
//! HLO train steps (JAX transformer + Pallas multi-LoRA kernel) on the
//! CPU client, reducing LoRA gradients deterministically before the
//! in-Rust Adam update. Logs the joint and per-task loss curves plus the
//! dispatch-clock GPU-seconds, proving the layers compose on a real
//! workload (recorded in EXPERIMENTS.md §E2E).
//!
//! ```bash
//! make artifacts                       # once (Python build path)
//! cargo run --release --example e2e_train -- [steps] [lr]
//! ```

use lobra::cluster::ClusterSpec;
use lobra::config::ModelDesc;
use lobra::coordinator::planner::DeploymentPlan;
use lobra::config::ParallelConfig;
use lobra::costmodel::CostModel;
use lobra::train::{Trainer, TrainerConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let lr: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2e-3);

    let mut cfg = TrainerConfig::default();
    cfg.adam.lr = lr;
    cfg.per_task_batch = 4;

    // Virtual cluster for GPU-seconds accounting of the same run.
    let model = ModelDesc::tiny();
    let cluster = ClusterSpec::local_cpu(4);
    let cost = CostModel::calibrated(&model, &cluster);
    let plan = DeploymentPlan {
        groups: vec![(ParallelConfig::new(1, 1), 4)],
        n_tasks: 6,
        expected_step_time: 0.0,
    };

    let mut trainer =
        Trainer::new("artifacts", cfg)?.with_virtual_cluster(cost, plan);
    let n_tasks = trainer.n_tasks();
    println!(
        "e2e joint LoRA FT: platform={} preset={} tasks={} lora_params={} shapes={:?}",
        trainer.engine().platform(),
        trainer.engine().manifest().preset,
        n_tasks,
        trainer.lora().len(),
        trainer.engine().shapes(),
    );
    println!("steps={steps} lr={lr}\n");
    println!("step,loss,{}", (0..n_tasks).map(|t| format!("task{t}")).collect::<Vec<_>>().join(","));

    let mut first_loss = None;
    trainer.run(steps, |log| {
        if first_loss.is_none() {
            first_loss = Some(log.loss);
        }
        if log.step == 1 || log.step % 10 == 0 {
            let tl: Vec<String> = log
                .task_loss
                .iter()
                .map(|o| o.map_or("".into(), |l| format!("{l:.4}")))
                .collect();
            println!("{},{:.4},{}", log.step, log.loss, tl.join(","));
        }
    })?;

    let logs = trainer.logs();
    let last = logs.last().unwrap();
    let first = first_loss.unwrap();
    let wall: f64 = logs.iter().map(|l| l.wall_seconds).sum();
    let virt: f64 = logs.iter().map(|l| l.virtual_seconds).sum();
    let virt_gpu: f64 = logs.iter().map(|l| l.virtual_gpu_seconds).sum();
    println!("\nsummary:");
    println!("  loss: {first:.4} -> {:.4} ({:.1}% reduction)", last.loss, (1.0 - last.loss / first) * 100.0);
    println!(
        "  wall: {wall:.1}s real CPU, {virt:.2}s virtual-cluster clock \
         ({virt_gpu:.2} GPU·s via MINMAX dispatch over [{}])",
        trainer.virtual_plan().notation()
    );
    // loss must actually go down for this to count as training
    assert!(
        last.loss < first * 0.9,
        "loss did not decrease enough: {first} -> {}",
        last.loss
    );
    println!("  OK: loss decreased through the full rust->PJRT->HLO(JAX+Pallas) stack");

    // per-task improvement
    let first_task: Vec<Option<f64>> = logs.first().unwrap().task_loss.clone();
    println!("\nper-task losses (first -> last):");
    for t in 0..n_tasks {
        if let (Some(a), Some(b)) = (first_task[t], last.task_loss[t]) {
            println!("  task {t}: {a:.4} -> {b:.4}");
        }
    }
    trainer.save_checkpoint("/tmp/lobra_e2e_lora.ckpt")?;
    println!("\ncheckpoint saved to /tmp/lobra_e2e_lora.ckpt");
    Ok(())
}
