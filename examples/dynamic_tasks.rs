//! Multi-tenant lifecycle scenario (paper §5.1 "dynamic batches"): FT
//! requests arrive and exit over time; the TaskManager re-plans on every
//! change and redeploys when the plan differs, checkpointing only the LoRA
//! adapters (the base model is shared and immutable).
//!
//! ```bash
//! cargo run --release --example dynamic_tasks
//! ```

use lobra::cluster::ClusterSpec;
use lobra::config::ModelDesc;
use lobra::coordinator::planner::PlannerOptions;
use lobra::coordinator::scheduler::{Scheduler, SchedulerOptions};
use lobra::coordinator::tasks::{Event, Outcome, TaskManager};
use lobra::costmodel::CostModel;
use lobra::data::{DatasetProfile, LengthDistribution};
use lobra::prelude::{TaskSet, TaskSpec};

fn main() {
    let model = ModelDesc::llama2_7b();
    let cluster = ClusterSpec::a100_40g(16);
    let cost = CostModel::calibrated(&model, &cluster);

    // Day 0: three instruction-tuning tenants.
    let initial = TaskSet::new(vec![
        TaskSpec::from_profile(DatasetProfile::by_name("databricks-dolly-15k").unwrap()),
        TaskSpec::from_profile(DatasetProfile::by_name("MathInstruct").unwrap()),
        TaskSpec::from_profile(DatasetProfile::by_name("MetaMathQA").unwrap()),
    ]);
    let mut mgr = TaskManager::new(&cost, &cluster, initial, PlannerOptions::default());
    println!("initial plan: [{}]\n", mgr.plan().unwrap().notation());

    let simulate = |mgr: &TaskManager, label: &str| {
        let Some(plan) = mgr.plan() else {
            println!("  ({label}: no active tasks)");
            return;
        };
        let mut sched = Scheduler::new(&cost, plan, mgr.tasks(), SchedulerOptions::default());
        let rep = sched.run_steps(20);
        println!("  {label}: {}", rep.summary());
    };
    simulate(&mgr, "steady state");

    // Event 1: a summarization tenant with very long sequences arrives.
    println!("\n>> MeetingBank arrives (long sequences)");
    let outcome = mgr.handle(Event::Arrive(TaskSpec::from_profile(
        DatasetProfile::by_name("MeetingBank").unwrap(),
    )));
    report(&outcome, &mgr);
    simulate(&mgr, "after arrival");

    // Event 2: a short-data tenant arrives; plan likely keeps shape.
    println!("\n>> small QA tenant arrives (short sequences)");
    let outcome = mgr.handle(Event::Arrive(TaskSpec::new(
        "tiny-qa",
        64,
        LengthDistribution::fit(150.0, 3.0, 16, 1024),
    )));
    report(&outcome, &mgr);
    simulate(&mgr, "after arrival");

    // Event 3: the long-sequence tenant finishes; capacity shifts back.
    println!("\n>> MeetingBank exits");
    let outcome = mgr.handle(Event::Exit { name: "MeetingBank".into() });
    report(&outcome, &mgr);
    simulate(&mgr, "after exit");

    println!("\ntotal redeployments: {}", mgr.redeploys);
}

fn report(outcome: &Outcome, mgr: &TaskManager) {
    match outcome {
        Outcome::Unchanged => println!("  plan unchanged — training continues"),
        Outcome::Redeployed { adjustment_seconds, adjustment } => println!(
            "  redeployed ({} replicas changed, ~{adjustment_seconds:.0}s adjustment)\n  new plan: [{}]",
            adjustment.changed_replicas,
            mgr.plan().unwrap().notation()
        ),
        Outcome::Drained => println!("  drained"),
        other => println!("  {other:?}"),
    }
}
