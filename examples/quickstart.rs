//! Quickstart: plan heterogeneous FT replicas and run a simulated joint-FT
//! session — the 60-second tour of the LobRA public API.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use lobra::prelude::*;

fn main() {
    // 1. Describe the world: base model, cluster, and the batch of FT
    //    requests (each request = dataset length profile + batch size).
    let model = ModelDesc::llama2_7b();
    let cluster = ClusterSpec::a100_40g(16);
    let tasks = TaskSet::paper_7b_subset();
    println!(
        "world: {} on {} with {} FT tasks (joint batch {})\n",
        model.name,
        cluster.name,
        tasks.len(),
        tasks.joint_batch()
    );

    // 2. Build the profiled cost model (paper Appendix D).
    let cost = CostModel::calibrated(&model, &cluster);

    // 3. Stage 1 (once): deployment planning — paper Eq. 2 with
    //    configuration proposal + lower-bound pruning.
    let planner = Planner::new(&cost, &cluster);
    let plan = planner
        .plan(&tasks, PlannerOptions::default())
        .expect("no feasible plan");
    println!("deployment plan (Table-2 notation): {}", plan.notation());
    println!(
        "  {} replicas over {} GPUs, expected step {:.2}s\n",
        plan.n_replicas(),
        plan.gpus_used(),
        plan.expected_step_time
    );

    // 4. Stage 2 (every step): dynamic bucketing + workload-balanced
    //    dispatch, executed on the simulated cluster.
    let mut sched = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default());
    let report = sched.run_steps(50);
    println!("joint FT over 50 steps:\n  {}", report.summary());

    // 5. Compare with the Task-Fused baseline: homogeneous replicas and no
    //    dynamic bucketing (the paper's naïve joint FT, Figure 4(b)).
    let fused = planner
        .plan_homogeneous(&tasks, &PlannerOptions::default())
        .expect("no homogeneous plan");
    let mut fused_opts = SchedulerOptions::default();
    fused_opts.dynamic_bucketing = false;
    let mut base = Scheduler::new(&cost, &fused, &tasks, fused_opts);
    let base_report = base.run_steps(50);
    println!("\nTask-Fused baseline ({}):\n  {}", fused.notation(), base_report.summary());
    println!(
        "\nLobRA reduces GPU seconds by {:.1}% vs Task-Fused",
        report.reduction_vs(&base_report) * 100.0
    );
}
