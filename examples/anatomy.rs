//! Reproduction of the paper's §3 design anatomy (Figure 4): four FT tasks
//! on 16 GPUs under the four designs —
//!
//!   (a) Task-Sequential: run the tasks one by one
//!   (b) naïve joint FT: homogeneous replicas + uniform dispatch
//!   (c) heterogeneous replicas + length-based dispatch
//!   (d) heterogeneous replicas + workload-balanced dispatch (LobRA)
//!
//! and the Figure 4(e) style dump of the Eq. 1 inputs/decisions.
//!
//! ```bash
//! cargo run --release --example anatomy
//! ```

use lobra::cluster::ClusterSpec;
use lobra::config::ModelDesc;
use lobra::coordinator::bucketing::Buckets;
use lobra::coordinator::dispatcher::{DispatchPolicy, Dispatcher};
use lobra::coordinator::planner::{Planner, PlannerOptions};
use lobra::coordinator::scheduler::{sequential_gpu_seconds, Scheduler, SchedulerOptions};
use lobra::costmodel::CostModel;
use lobra::data::LengthDistribution;
use lobra::prelude::{TaskSet, TaskSpec};

fn main() {
    // Four tasks with increasingly long data — the Figure 4 setup.
    let tasks = TaskSet::new(vec![
        TaskSpec::new("qa-short", 128, LengthDistribution::fit(180.0, 4.0, 16, 1024)),
        TaskSpec::new("instruct", 96, LengthDistribution::fit(450.0, 2.5, 16, 3000)),
        TaskSpec::new("code", 40, LengthDistribution::fit(1200.0, 1.2, 16, 7000)),
        TaskSpec::new("summarize", 14, LengthDistribution::fit(5200.0, 0.8, 64, 14000)),
    ]);
    let model = ModelDesc::llama2_7b();
    let cluster = ClusterSpec::a100_40g(16);
    let cost = CostModel::calibrated(&model, &cluster);
    let planner = Planner::new(&cost, &cluster);

    println!("== Figure 4 anatomy: 4 FT tasks, {} ==\n", cluster.name);

    // (a) Task-Sequential
    let (seq_total, per_task) = sequential_gpu_seconds(
        &cost, &cluster, &tasks, false, 20, &SchedulerOptions::default());
    println!("(a) Task-Sequential       : {seq_total:9.2} GPU·s/step");
    for (name, gs) in &per_task {
        println!("      {name:<12} {gs:8.2}");
    }

    // (b) naïve: homogeneous replicas
    let fused = planner.plan_homogeneous(&tasks, &PlannerOptions::default()).unwrap();
    let rb = Scheduler::new(&cost, &fused, &tasks, SchedulerOptions::default()).run_steps(20);
    println!(
        "(b) homogeneous + balanced: {:9.2} GPU·s/step  plan [{}]",
        rb.gpu_seconds_per_step,
        fused.notation()
    );

    // (c) heterogeneous + length-based
    let plan = planner.plan(&tasks, PlannerOptions::default()).unwrap();
    let mut o_len = SchedulerOptions::default();
    o_len.policy = DispatchPolicy::LengthBased;
    let rc = Scheduler::new(&cost, &plan, &tasks, o_len).run_steps(20);
    println!(
        "(c) hetero + length-based : {:9.2} GPU·s/step  plan [{}]  util {:.0}%",
        rc.gpu_seconds_per_step,
        plan.notation(),
        rc.utilization * 100.0
    );

    // (d) heterogeneous + workload-balanced (LobRA)
    let rd = Scheduler::new(&cost, &plan, &tasks, SchedulerOptions::default()).run_steps(20);
    println!(
        "(d) hetero + balanced     : {:9.2} GPU·s/step  util {:.0}%",
        rd.gpu_seconds_per_step,
        rd.utilization * 100.0
    );

    println!(
        "\nreduction (d) vs (b): {:.1}%   (d) vs (c): {:.1}%",
        (1.0 - rd.gpu_seconds_per_step / rb.gpu_seconds_per_step) * 100.0,
        (1.0 - rd.gpu_seconds_per_step / rc.gpu_seconds_per_step) * 100.0
    );

    // Figure 4(e): inputs + decision variables of Eq. 1 for one batch.
    println!("\n== Figure 4(e): one dispatch instance ==");
    let boundaries = vec![512, 2048, 8192, 16384];
    let counts = vec![196, 62, 16, 4];
    let buckets = Buckets { boundaries: boundaries.clone(), counts: counts.clone(), padding_tokens: 0 };
    let dispatcher = Dispatcher::new(&cost, &plan);
    let dp = dispatcher.dispatch(&buckets, DispatchPolicy::Balanced).unwrap();
    println!("buckets B_j = {counts:?} at boundaries {boundaries:?}");
    for (i, (cfg, p)) in dp.groups.iter().enumerate() {
        println!("  d[{cfg}x{p}] = {:?}", dp.d[i]);
    }
    println!("predicted step time: {:.2}s", dp.predicted_step_time);
}
